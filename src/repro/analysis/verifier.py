"""Static offload verifier: walk the descriptor, not the kernel.

The paper's central lesson is that offload correctness and cost live in
the *descriptor* — hazards, completion races and mis-sized windows are
knowable before a single cycle runs.  :func:`verify_graph` walks a
``submit_graph`` node list (and :func:`verify` a single submit) against
the same invariants the runtime enforces piecemeal, reporting every
finding as a typed :class:`~repro.analysis.diagnostics.Diagnostic`
with a stable ``OFL###`` code instead of the first ad-hoc exception.

:class:`Session` runs these automatically at the top of ``submit`` /
``submit_graph`` (disable with ``Session(verify=False)``); error-severity
findings raise :class:`VerificationError` — a :class:`~repro.core.
scoreboard.GraphError` subclass, so existing ``except GraphError``
call sites keep working — before any staging touches a device.

Checks are conservative: a fact the verifier cannot establish statically
(mask-encoded selections, ``Residency.RESIDENT`` operand shapes, foreign
sessions) is skipped, never guessed — a defect-free graph verifies clean.
Producer output shapes are propagated through the DAG with
``jax.eval_shape`` over the jobs' *global* computations (abstract
tracing only — no device work; memoized per (kernel, shapes)).
"""

from __future__ import annotations

import collections
from typing import (
    Any, Dict, List, Mapping, Optional, Sequence, Tuple,
)

from repro.core.policy import OffloadPolicy, Residency, RetryPolicy, Staging
from repro.core.scoreboard import GraphError, GraphNode, Ref

from .diagnostics import (
    Diagnostic, Severity, contradiction, invalid_field, invalid_mode,
    use_after_donate,
)

__all__ = [
    "VerificationError", "verify", "verify_graph", "verify_policy",
]


class VerificationError(GraphError):
    """Static verification found error-severity diagnostics.

    Subclasses :class:`~repro.core.scoreboard.GraphError` (itself a
    ``ValueError``) so pre-verifier ``except`` clauses keep catching
    malformed graphs; ``.diagnostics`` carries the typed findings and
    ``.codes`` their stable codes.
    """

    def __init__(self, diagnostics: Sequence[Diagnostic]):
        self.diagnostics: List[Diagnostic] = list(diagnostics)
        lines = "\n  ".join(str(d) for d in self.diagnostics)
        super().__init__(
            f"static verification failed ({len(self.diagnostics)} "
            f"diagnostic(s)):\n  {lines}")

    @property
    def codes(self) -> Tuple[str, ...]:
        return tuple(d.code for d in self.diagnostics)


def raise_errors(diags: Sequence[Diagnostic]) -> None:
    """Raise :class:`VerificationError` for error-severity findings."""
    errors = [d for d in diags if d.severity is Severity.ERROR]
    if errors:
        raise VerificationError(errors)


# -- helpers -----------------------------------------------------------------


def _is_deleted(value: Any) -> bool:
    """Duck-typed donated-buffer probe (jax arrays grow ``is_deleted``)."""
    probe = getattr(value, "is_deleted", None)
    return callable(probe) and bool(probe())


def _shape_of(value: Any) -> Optional[Tuple[int, ...]]:
    shape = getattr(value, "shape", None)
    if shape is not None:
        return tuple(shape)
    try:
        import numpy as np
        return tuple(np.asarray(value).shape)
    except Exception:                                      # noqa: BLE001
        return None


#: memoized eval_shape results: (kernel id, sorted shapes) -> out shape
_SHAPE_CACHE: Dict[Tuple, Tuple[str, Any]] = {}


def _eval_out_shape(job: Any, shapes: Mapping[str, Tuple[int, ...]]
                    ) -> Tuple[str, Any]:
    """-> ("ok", out_shape) | ("fail", reason) | ("skip", None).

    Abstractly traces the job's *global* computation over the inferred
    operand shapes — the runtime contract is that the graph result of a
    node has this shape (sharded outputs reassemble to it, reduced and
    broadcast-class outputs equal it outright).
    """
    key = (id(job.compute), tuple(sorted(shapes.items())))
    hit = _SHAPE_CACHE.get(key)
    if hit is not None:
        return hit
    try:
        import jax
    except Exception:                                      # noqa: BLE001
        return ("skip", None)
    try:
        structs = [jax.ShapeDtypeStruct(shapes[name], "float32")
                   for name in sorted(shapes)]
        out = jax.eval_shape(job.compute, *structs)
        result: Tuple[str, Any] = ("ok", tuple(out.shape))
    except Exception as e:                                 # noqa: BLE001
        result = ("fail", f"{type(e).__name__}: {e}")
    if len(_SHAPE_CACHE) > 512:
        _SHAPE_CACHE.clear()
    _SHAPE_CACHE[key] = result
    return result


def _node_width(nd: GraphNode, default_width: Optional[int],
                session: Any) -> Optional[int]:
    """Statically-known cluster-selection size of a node (None = unknown)."""
    if nd.clusters is not None:
        return len(set(int(c) for c in nd.clusters))
    if nd.request is not None:
        return None          # mask-encoded; the runtime resolves it
    if nd.n is not None:
        return int(nd.n)
    if nd.session is not None and nd.session is not session:
        return None          # a foreign lease's width is its business
    return default_width


def _resolve_ref(node: Any, names: Mapping[str, int], n_nodes: int
                 ) -> Optional[int]:
    if isinstance(node, str):
        return names.get(node)
    try:
        idx = int(node)
    except (TypeError, ValueError):
        return None
    return idx if 0 <= idx < n_nodes else None


# -- the passes --------------------------------------------------------------


def verify_policy(policy: Optional[OffloadPolicy] = None,
                  **fields: Any) -> List[Diagnostic]:
    """Validate policy fields without constructing (or raising).

    With ``policy`` given its (already-validated) fields seed the check;
    ``fields`` override/extend with raw values — the pre-flight a config
    loader runs before ``OffloadPolicy(**fields)`` would raise.  Returns
    OFL008 (bad mode value), OFL009 (out-of-range field) and OFL010
    (contradiction) diagnostics.
    """
    from repro.core.policy import Completion, InfoDist
    merged: Dict[str, Any] = {}
    if policy is not None:
        for f in ("staging", "residency", "info_dist", "completion",
                  "fuse", "window", "depth", "donate_operands", "retry"):
            merged[f] = getattr(policy, f)
    merged.update(fields)

    diags: List[Diagnostic] = []
    enums = (("staging", Staging, True), ("residency", Residency, False),
             ("info_dist", InfoDist, False), ("completion", Completion, False))
    coerced: Dict[str, Any] = {}
    for field, enum_cls, optional in enums:
        value = merged.get(field)
        if value is None:
            if not optional and field in merged:
                diags.append(invalid_mode(field, value,
                                          tuple(m.value for m in enum_cls)))
            continue
        try:
            coerced[field] = enum_cls(value)
        except ValueError:
            diags.append(invalid_mode(field, value,
                                      tuple(m.value for m in enum_cls)))
    for field in ("fuse", "window", "depth"):
        v = merged.get(field)
        if v is not None and (not isinstance(v, int) or v < 1):
            diags.append(invalid_field(
                field, f"{field} must be an int >= 1, got {v!r}"))
    retry = merged.get("retry")
    if retry is not None and not isinstance(retry, RetryPolicy):
        diags.append(invalid_field(
            "retry", f"retry must be a RetryPolicy, got "
                     f"{type(retry).__name__}"))
    if (coerced.get("residency") is Residency.RESIDENT
            and coerced.get("staging") is not None
            and coerced.get("staging") is not Staging.DIRECT):
        diags.append(contradiction(
            f"residency=RESIDENT stages no operands; pinning "
            f"staging={coerced['staging'].value!r} is contradictory "
            "(leave staging unset or DIRECT)", name="staging"))
    return diags


def verify(job: Any, policy: Optional[OffloadPolicy] = None,
           lease: Any = None, *,
           operands: Any = None,
           n: Optional[int] = None,
           clusters: Optional[Sequence[int]] = None,
           n_units: int = 4) -> List[Diagnostic]:
    """Statically verify one submit: (job, policy, lease, operands).

    Returns every finding (errors *and* warnings); ``Session.submit``
    raises the error subset through the OFL003 donation shim.  Checks:
    deleted operand buffers (OFL003), operand-name and shard-axis
    divisibility mismatches (OFL006), policy contradictions
    (OFL008/9/10 via :func:`verify_policy`), and an inactive lease
    (OFL011).
    """
    diags: List[Diagnostic] = []
    if policy is not None:
        diags.extend(verify_policy(policy))
    if lease is not None and not getattr(lease, "active", True):
        diags.append(Diagnostic(
            "OFL011",
            f"lease {getattr(lease, 'lease_id', '?')} over clusters "
            f"{tuple(getattr(lease, 'clusters', ()))} is no longer "
            "active (released, revoked, or resized away)"))

    if operands is None or isinstance(operands, (Residency, str)):
        return diags
    instances = (list(operands) if isinstance(operands, (list, tuple))
                 else [operands])
    width: Optional[int] = None
    if clusters is not None:
        width = len(set(int(c) for c in clusters))
    elif n is not None:
        width = int(n)
    elif lease is not None and getattr(lease, "clusters", None) is not None:
        width = len(lease.clusters)
    shard_axes = getattr(job, "shard_axes", None)
    for b, inst in enumerate(instances):
        if not isinstance(inst, Mapping):
            continue
        tag = f" (instance {b})" if len(instances) > 1 else ""
        for name, value in inst.items():
            if _is_deleted(value):
                diags.append(use_after_donate(
                    f"submitted operand {name!r}{tag}", name=name))
        if shard_axes is None:
            continue
        if set(inst) != set(shard_axes):
            diags.append(Diagnostic(
                "OFL006",
                f"operand names {sorted(inst)}{tag} do not match job "
                f"{job.spec.name}'s {sorted(shard_axes)}"))
            continue
        if not width:
            continue
        for name, value in inst.items():
            axis = shard_axes[name]
            shape = _shape_of(value)
            if axis is None or shape is None or axis >= len(shape):
                continue
            if shape[axis] % width:
                diags.append(Diagnostic(
                    "OFL006",
                    f"operand {name!r}{tag} axis {axis} ({shape[axis]}) "
                    f"not divisible by {width} clusters", name=name))
    return diags


def verify_graph(nodes: Sequence[GraphNode], *,
                 policy: Optional[OffloadPolicy] = None,
                 n_units: int = 4,
                 default_width: Optional[int] = None,
                 session: Any = None) -> List[Diagnostic]:
    """Statically verify a ``submit_graph`` node list.

    Walks structure (OFL001 cycles, OFL002 dangling/malformed
    references), donated operand buffers (OFL003), donation renames
    (OFL004, warning), cross-lease circular waits (OFL005, warning),
    shard/forward-edge shape consistency (OFL006 — producer output
    shapes propagated via ``jax.eval_shape``), graph width vs the
    in-flight window (OFL007, warning) and the graph-policy
    contradiction (OFL010).  Structural errors short-circuit the deeper
    passes (their node indices would be unreliable).

    ``default_width`` is the submitting session's device count (the
    selection a node with no ``n``/``clusters``/``request`` gets);
    ``session`` identifies that session so foreign-lease nodes are
    skipped conservatively.
    """
    diags: List[Diagnostic] = []
    nodes = list(nodes)
    if not nodes:
        return [Diagnostic("OFL002", "empty graph")]
    for i, nd in enumerate(nodes):
        if not isinstance(nd, GraphNode):
            diags.append(Diagnostic(
                "OFL002", f"entry {i} is not a GraphNode "
                          f"(got {type(nd).__name__})", node=i))
    if diags:
        return diags

    n_nodes = len(nodes)
    names: Dict[str, int] = {}
    for i, nd in enumerate(nodes):
        if nd.name is None:
            continue
        if nd.name in names:
            diags.append(Diagnostic(
                "OFL002", f"duplicate node name {nd.name!r} (nodes "
                          f"{names[nd.name]} and {i})", node=i,
                name=nd.name))
        else:
            names[nd.name] = i

    deps: List[List[int]] = []
    data_edges: List[List[Tuple[int, str]]] = []
    for i, nd in enumerate(nodes):
        where = f"node {i}" + (f" ({nd.name})" if nd.name else "")
        d: set = set()
        edges: List[Tuple[int, str]] = []
        if isinstance(nd.operands, Mapping):
            for op_name, value in nd.operands.items():
                if not isinstance(value, Ref):
                    continue
                src = _resolve_ref(value.node, names, n_nodes)
                if src is None:
                    diags.append(Diagnostic(
                        "OFL002",
                        f"{where} operand {op_name!r}: dangling Ref "
                        f"{value.node!r} (known names: {sorted(names)}, "
                        f"indices [0, {n_nodes}))", node=i, name=nd.name))
                elif src == i:
                    diags.append(Diagnostic(
                        "OFL001", f"{where} operand {op_name!r} depends "
                                  "on the node itself", node=i,
                        name=nd.name))
                else:
                    edges.append((src, op_name))
                    d.add(src)
        elif not isinstance(nd.operands, Residency):
            diags.append(Diagnostic(
                "OFL002",
                f"{where}: operands must be a mapping or "
                f"Residency.RESIDENT, got {type(nd.operands).__name__}",
                node=i, name=nd.name))
        for ref in nd.after:
            src = _resolve_ref(ref.node if isinstance(ref, Ref) else ref,
                               names, n_nodes)
            if src is None:
                diags.append(Diagnostic(
                    "OFL002", f"{where} after: dangling reference "
                              f"{ref!r}", node=i, name=nd.name))
            elif src == i:
                diags.append(Diagnostic(
                    "OFL001", f"{where} after: depends on itself",
                    node=i, name=nd.name))
            else:
                d.add(src)
        deps.append(sorted(d))
        data_edges.append(edges)
    if diags:
        return diags

    # cycle detection (Kahn) + the topological order the shape pass uses
    succs: List[List[int]] = [[] for _ in range(n_nodes)]
    indeg = [len(d) for d in deps]
    for i, d in enumerate(deps):
        for p in d:
            succs[p].append(i)
    queue = collections.deque(i for i, k in enumerate(indeg) if k == 0)
    topo: List[int] = []
    while queue:
        i = queue.popleft()
        topo.append(i)
        for s in succs[i]:
            indeg[s] -= 1
            if indeg[s] == 0:
                queue.append(s)
    if len(topo) != n_nodes:
        stuck = sorted(i for i, k in enumerate(indeg) if k > 0)
        diags.append(Diagnostic(
            "OFL001", f"dependency cycle through nodes {stuck}",
            node=stuck[0]))
        return diags

    pol = policy
    if pol is not None:
        if pol.retry is not None:
            diags.append(contradiction(
                "graph submits do not ride the retry/deadline ladder; "
                "drop policy.retry (wrap individual submits for "
                "fault-tolerant dispatch)", name="retry"))
        diags.extend(verify_policy(pol))

    # OFL003: an operand buffer a donating dispatch already consumed
    for i, nd in enumerate(nodes):
        if not isinstance(nd.operands, Mapping):
            continue
        for op_name, value in nd.operands.items():
            if not isinstance(value, Ref) and _is_deleted(value):
                diags.append(use_after_donate(
                    f"node {i} operand {op_name!r}", node=i,
                    name=nd.name))
    if any(d.severity is Severity.ERROR for d in diags):
        return diags

    # OFL004 (warning): donation renames every forwarded read
    if pol is not None and pol.donate_operands:
        reads: Dict[int, int] = collections.Counter(
            src for i in range(n_nodes) for src, _ in data_edges[i])
        for src in sorted(reads):
            diags.append(Diagnostic(
                "OFL004",
                f"donating policy: {reads[src]} forwarded read(s) of "
                f"node {src}'s result will be renamed (copied) to break "
                "the WAR/WAW hazard", severity=Severity.WARNING,
                node=src, name=nodes[src].name))

    # OFL006: shard divisibility + forward-edge shape propagation
    out_shape: List[Optional[Tuple[int, ...]]] = [None] * n_nodes
    edge_src = [dict((op, src) for src, op in data_edges[i])
                for i in range(n_nodes)]
    for i in topo:
        nd = nodes[i]
        if not isinstance(nd.operands, Mapping):
            continue
        shard_axes = getattr(nd.job, "shard_axes", None)
        if shard_axes is not None and set(nd.operands) != set(shard_axes):
            diags.append(Diagnostic(
                "OFL006",
                f"node {i} operand names {sorted(nd.operands)} do not "
                f"match job {nd.job.spec.name}'s {sorted(shard_axes)}",
                node=i, name=nd.name))
            continue
        shapes: Dict[str, Optional[Tuple[int, ...]]] = {}
        for op_name, value in nd.operands.items():
            if isinstance(value, Ref):
                shapes[op_name] = out_shape[edge_src[i][op_name]]
            else:
                shapes[op_name] = _shape_of(value)
        width = _node_width(nd, default_width, session)
        if shard_axes is not None and width:
            for op_name, shape in shapes.items():
                axis = shard_axes[op_name]
                if axis is None or shape is None or axis >= len(shape):
                    continue
                if shape[axis] % width:
                    via = (" (forwarded from node "
                           f"{edge_src[i][op_name]})"
                           if op_name in edge_src[i] else "")
                    diags.append(Diagnostic(
                        "OFL006",
                        f"node {i} operand {op_name!r}{via} axis {axis} "
                        f"({shape[axis]}) not divisible by {width} "
                        "clusters", node=i, name=nd.name))
        if shapes and all(s is not None for s in shapes.values()):
            status, out = _eval_out_shape(nd.job, shapes)  # type: ignore[arg-type]
            if status == "ok":
                out_shape[i] = out
            elif status == "fail":
                diags.append(Diagnostic(
                    "OFL006",
                    f"node {i}: operands {dict(sorted(shapes.items()))} "
                    f"are not shape-consistent for job "
                    f"{nd.job.spec.name}: {out}", node=i, name=nd.name))

    # OFL007 (warning): peak ready-width vs the in-flight window
    limit = max(1, min(pol.window if pol is not None and pol.window
                       is not None else n_units, n_units))
    level = [0] * n_nodes
    for i in topo:
        level[i] = 1 + max((level[p] for p in deps[i]), default=-1)
    width_per_level = collections.Counter(level)
    peak = max(width_per_level.values())
    if peak > limit:
        widest = max(width_per_level, key=lambda lv: width_per_level[lv])
        diags.append(Diagnostic(
            "OFL007",
            f"graph width {peak} (level {widest}) exceeds the in-flight "
            f"window {limit}; issue will stall draining the oldest "
            "in-flight job", severity=Severity.WARNING))

    # OFL005 (warning): condensed lease graph must not cycle
    group_of = [id(nd.session) if nd.session is not None else 0
                for nd in nodes]
    group_edges: Dict[int, set] = collections.defaultdict(set)
    for i, d in enumerate(deps):
        for p in d:
            if group_of[p] != group_of[i]:
                group_edges[group_of[p]].add(group_of[i])
    WHITE, GRAY, BLACK = 0, 1, 2
    color: Dict[int, int] = collections.defaultdict(int)

    def _cycles_from(g: int) -> bool:
        stack = [(g, iter(group_edges.get(g, ())))]
        color[g] = GRAY
        while stack:
            node, it = stack[-1]
            advanced = False
            for nxt in it:
                if color[nxt] == GRAY:
                    return True
                if color[nxt] == WHITE:
                    color[nxt] = GRAY
                    stack.append((nxt, iter(group_edges.get(nxt, ()))))
                    advanced = True
                    break
            if not advanced:
                color[node] = BLACK
                stack.pop()
        return False

    if any(color[g] == WHITE and _cycles_from(g)
           for g in list(group_edges)):
        diags.append(Diagnostic(
            "OFL005",
            "dependency edges cross session leases in a cycle — the "
            "leases cannot drain independently (a distributed "
            "dispatcher would circular-wait)",
            severity=Severity.WARNING))

    return diags
