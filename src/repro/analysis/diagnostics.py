"""The stable diagnostic vocabulary of the static offload verifier.

Eight PRs of runtime invariants — donation rules, WAR/WAW renaming,
lease residency, policy contradictions, in-flight window bounds — were
each enforced by a scattered ad-hoc exception that fired *after*
dispatch.  This module is the compiler-front-end answer: one table of
stable ``OFL###`` codes, each with a severity, a one-line title, and a
long-form ``explain()`` text, plus the typed :class:`Diagnostic` record
every verifier pass and every legacy-exception shim reports through.

The module is deliberately dependency-free (no jax, no other ``repro``
imports): :mod:`repro.core.policy` raises through it from failure
branches, so it must sit below every core module in the import graph.
"""

from __future__ import annotations

import dataclasses
import enum
import json
from typing import Any, Dict, Mapping, Optional, Tuple, Type

__all__ = [
    "CODES", "Diagnostic", "Severity", "contradiction", "explain",
    "invalid_field", "invalid_mode", "use_after_donate",
]


class Severity(str, enum.Enum):
    """How a diagnostic gates a submit: ``ERROR`` raises before any
    staging, ``WARNING`` is advisory (the runtime handles the hazard —
    e.g. by renaming — but the descriptor could be cheaper without it).
    """

    ERROR = "error"
    WARNING = "warning"


@dataclasses.dataclass(frozen=True)
class _CodeInfo:
    title: str
    severity: Severity
    explain: str


#: The stable code table.  Codes are append-only: a released code keeps
#: its number and meaning forever (tests snapshot this table the way
#: ``tests/test_api_surface.py`` snapshots the API).
CODES: Dict[str, _CodeInfo] = {
    "OFL001": _CodeInfo(
        "dependency cycle", Severity.ERROR,
        "The graph's dataflow (Ref operands) and ordering (after=) edges "
        "form a cycle, so no issue order exists: the scoreboard could "
        "never find a ready node.  A node depending on itself is the "
        "degenerate one-node cycle.  Break the cycle by removing an edge "
        "or splitting the graph into two submits."),
    "OFL002": _CodeInfo(
        "dangling or malformed node reference", Severity.ERROR,
        "A Ref or after= entry names a node that does not exist (unknown "
        "name, index outside the node list), two nodes share a name, an "
        "entry is not a GraphNode, a node's operands are not a mapping "
        "or Residency.RESIDENT, or the graph is empty.  The reference "
        "can never resolve to a producer result."),
    "OFL003": _CodeInfo(
        "use-after-donate", Severity.ERROR,
        "An operand (or forwarded producer result) is a device buffer "
        "that a donating dispatch already consumed — XLA deleted it on "
        "launch.  Restage the value from its host copy "
        "(plan.resident_operands restores resident buffers "
        "automatically) or disable donate_operands for buffers that "
        "must stay readable."),
    "OFL004": _CodeInfo(
        "WAR/WAW rename required", Severity.WARNING,
        "Under donate_operands a consumer launch would consume a "
        "forwarded producer buffer that other readers (or a later "
        "fetch) still need.  The graph dispatcher renames — copies — "
        "the buffer before the donating consumer, so the run is "
        "correct, but each such edge pays one device-side copy "
        "(PlanStats.renames).  Disable donation for the graph policy "
        "to forward by aliasing instead."),
    "OFL005": _CodeInfo(
        "cross-lease circular wait", Severity.WARNING,
        "The graph's dependency edges cross session leases in a cycle "
        "(lease A waits on lease B which waits on lease A).  The "
        "single-host scoreboard still finds an issue order, but the "
        "leases cannot drain independently — a distributed dispatcher "
        "would deadlock.  Restructure so cross-lease edges flow one "
        "way, or keep the cyclic portion inside one lease."),
    "OFL006": _CodeInfo(
        "sharding mismatch", Severity.ERROR,
        "An operand's shard axis is not divisible by the node's cluster "
        "selection, or a forwarded producer result's shape cannot "
        "satisfy the consumer kernel — the dispatch plan could never "
        "build.  Resize the operand, change the selection width, or fix "
        "the forward edge."),
    "OFL007": _CodeInfo(
        "graph width exceeds the in-flight window", Severity.WARNING,
        "More nodes become ready at once than the in-flight window "
        "(policy.window, capped by the runtime's completion-unit "
        "copies) can hold, so issue will stall draining the oldest "
        "in-flight job (InflightWindow.stalls counts these).  Raise "
        "policy.window / n_units, or narrow the graph."),
    "OFL008": _CodeInfo(
        "invalid mode value", Severity.ERROR,
        "A mode field (staging, residency, info_dist, completion, via) "
        "is not a member of its enum — a typo like "
        "info_dist='mulitcast' would otherwise silently misconfigure "
        "the run.  Use the typed enums from repro.api."),
    "OFL009": _CodeInfo(
        "invalid policy field", Severity.ERROR,
        "A numeric or typed policy field is out of range: fuse/window/"
        "depth below 1, RetryPolicy bounds (max_attempts >= 1, "
        "deadline_factor > 1, backoff >= 1), or a field of the wrong "
        "type."),
    "OFL010": _CodeInfo(
        "policy contradiction", Severity.ERROR,
        "Two policy fields cannot hold at once: residency=RESIDENT "
        "stages no operands so a pinned non-DIRECT staging could never "
        "run, and graph submits do not ride the retry/deadline ladder "
        "(policy.retry must be None for submit_graph)."),
    "OFL011": _CodeInfo(
        "inactive lease", Severity.ERROR,
        "The submit targets a lease that is no longer active — it was "
        "released, revoked, or superseded by a resize.  Request a new "
        "lease from the scheduler (or use the current lease object) "
        "before submitting."),
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One typed finding of the static verifier (or a runtime shim).

    ``code`` indexes :data:`CODES`; ``node``/``name`` locate the
    offending graph node (index and, when it has one, its
    ``GraphNode.name`` — or the offending policy/operand field);
    ``suggestion`` is the actionable fix (defaulted from the code
    table's explain text when left empty).
    """

    code: str
    message: str
    severity: Severity = Severity.ERROR
    node: Optional[int] = None
    name: Optional[str] = None
    suggestion: str = ""

    def __post_init__(self) -> None:
        if self.code not in CODES:
            raise ValueError(f"unknown diagnostic code {self.code!r} "
                             f"(known: {sorted(CODES)})")
        info = CODES[self.code]
        object.__setattr__(self, "severity", Severity(self.severity))
        if not self.suggestion:
            object.__setattr__(self, "suggestion", info.explain)

    @property
    def title(self) -> str:
        return CODES[self.code].title

    def __str__(self) -> str:
        where = ""
        if self.node is not None:
            where = f" [node {self.node}" + (
                f" ({self.name})]" if self.name else "]")
        elif self.name is not None:
            where = f" [{self.name}]"
        return f"{self.code}: {self.message}{where}"

    def to_json(self) -> str:
        """Stable JSON serialization (round-trips via :meth:`from_json`)."""
        return json.dumps({
            "code": self.code,
            "title": self.title,
            "severity": self.severity.value,
            "message": self.message,
            "node": self.node,
            "name": self.name,
            "suggestion": self.suggestion,
        }, sort_keys=True)

    @classmethod
    def from_json(cls, payload: str) -> "Diagnostic":
        d: Mapping[str, Any] = json.loads(payload)
        return cls(code=d["code"], message=d["message"],
                   severity=Severity(d["severity"]), node=d.get("node"),
                   name=d.get("name"), suggestion=d.get("suggestion", ""))

    def as_error(self, cls: Type[Exception] = ValueError) -> Exception:
        """This diagnostic as a raisable exception of type ``cls``.

        The legacy-exception shims use this: the raised error keeps its
        historical type (so existing ``except`` clauses keep working)
        while carrying ``.code`` and ``.diagnostic`` for new callers.
        """
        err = cls(str(self))
        err.code = self.code                 # type: ignore[attr-defined]
        err.diagnostic = self                # type: ignore[attr-defined]
        return err


def explain(code: str) -> str:
    """Long-form explanation of a diagnostic code (``OFL001``...)."""
    info = CODES.get(code)
    if info is None:
        raise KeyError(f"unknown diagnostic code {code!r} "
                       f"(known: {sorted(CODES)})")
    return f"{code} [{info.severity.value}] {info.title}: {info.explain}"


# -- shim constructors (the core modules raise through these) ----------------


def invalid_mode(field: str, value: Any,
                 valid: Tuple[str, ...]) -> Diagnostic:
    """OFL008: an enum-valued mode field rejected a value."""
    return Diagnostic("OFL008", f"{field} {value!r} not in {valid}",
                      name=field)


def invalid_field(field: str, message: str) -> Diagnostic:
    """OFL009: a policy field failed its range/type validation."""
    return Diagnostic("OFL009", message, name=field)


def contradiction(message: str, name: Optional[str] = None) -> Diagnostic:
    """OFL010: two policy fields cannot hold at once."""
    return Diagnostic("OFL010", message, name=name)


def use_after_donate(what: str, node: Optional[int] = None,
                     name: Optional[str] = None) -> Diagnostic:
    """OFL003: a donated (deleted) device buffer would be read."""
    return Diagnostic(
        "OFL003", f"{what} was deleted by a donating dispatch",
        node=node, name=name,
        suggestion=(
            "restage it from the host copy (plan.resident_operands "
            "restores resident buffers automatically) or disable "
            "donate_operands for buffers that must stay readable"))
