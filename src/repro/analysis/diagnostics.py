"""The stable diagnostic vocabulary of the static offload verifier.

Eight PRs of runtime invariants — donation rules, WAR/WAW renaming,
lease residency, policy contradictions, in-flight window bounds — were
each enforced by a scattered ad-hoc exception that fired *after*
dispatch.  This module is the compiler-front-end answer: one table of
stable ``OFL###`` codes, each with a severity, a one-line title, and a
long-form ``explain()`` text, plus the typed :class:`Diagnostic` record
every verifier pass and every legacy-exception shim reports through.

The module is deliberately dependency-free (no jax, no other ``repro``
imports): :mod:`repro.core.policy` raises through it from failure
branches, so it must sit below every core module in the import graph.
"""

from __future__ import annotations

import collections
import dataclasses
import difflib
import enum
import json
from typing import (
    Any, Deque, Dict, Iterable, Iterator, List, Mapping, Optional, Tuple,
    Type,
)

__all__ = [
    "CODES", "Diagnostic", "DiagnosticsLog", "Severity",
    "UnknownDiagnosticCode", "contradiction", "explain", "invalid_field",
    "invalid_mode", "use_after_donate",
]


class Severity(str, enum.Enum):
    """How a diagnostic gates a submit: ``ERROR`` raises before any
    staging, ``WARNING`` is advisory (the runtime handles the hazard —
    e.g. by renaming — but the descriptor could be cheaper without it),
    and ``PERF`` never gates — the descriptor is *correct* but the §6
    cost model predicts a cheaper configuration (``OFLP1##`` codes from
    :mod:`repro.analysis.perflint`, each carrying a predicted cycle
    delta and a machine-applicable fix).
    """

    ERROR = "error"
    WARNING = "warning"
    PERF = "perf"


@dataclasses.dataclass(frozen=True)
class _CodeInfo:
    title: str
    severity: Severity
    explain: str


#: The stable code table.  Codes are append-only: a released code keeps
#: its number and meaning forever (tests snapshot this table the way
#: ``tests/test_api_surface.py`` snapshots the API).
CODES: Dict[str, _CodeInfo] = {
    "OFL001": _CodeInfo(
        "dependency cycle", Severity.ERROR,
        "The graph's dataflow (Ref operands) and ordering (after=) edges "
        "form a cycle, so no issue order exists: the scoreboard could "
        "never find a ready node.  A node depending on itself is the "
        "degenerate one-node cycle.  Break the cycle by removing an edge "
        "or splitting the graph into two submits."),
    "OFL002": _CodeInfo(
        "dangling or malformed node reference", Severity.ERROR,
        "A Ref or after= entry names a node that does not exist (unknown "
        "name, index outside the node list), two nodes share a name, an "
        "entry is not a GraphNode, a node's operands are not a mapping "
        "or Residency.RESIDENT, or the graph is empty.  The reference "
        "can never resolve to a producer result."),
    "OFL003": _CodeInfo(
        "use-after-donate", Severity.ERROR,
        "An operand (or forwarded producer result) is a device buffer "
        "that a donating dispatch already consumed — XLA deleted it on "
        "launch.  Restage the value from its host copy "
        "(plan.resident_operands restores resident buffers "
        "automatically) or disable donate_operands for buffers that "
        "must stay readable."),
    "OFL004": _CodeInfo(
        "WAR/WAW rename required", Severity.WARNING,
        "Under donate_operands a consumer launch would consume a "
        "forwarded producer buffer that other readers (or a later "
        "fetch) still need.  The graph dispatcher renames — copies — "
        "the buffer before the donating consumer, so the run is "
        "correct, but each such edge pays one device-side copy "
        "(PlanStats.renames).  Disable donation for the graph policy "
        "to forward by aliasing instead."),
    "OFL005": _CodeInfo(
        "cross-lease circular wait", Severity.WARNING,
        "The graph's dependency edges cross session leases in a cycle "
        "(lease A waits on lease B which waits on lease A).  The "
        "single-host scoreboard still finds an issue order, but the "
        "leases cannot drain independently — a distributed dispatcher "
        "would deadlock.  Restructure so cross-lease edges flow one "
        "way, or keep the cyclic portion inside one lease."),
    "OFL006": _CodeInfo(
        "sharding mismatch", Severity.ERROR,
        "An operand's shard axis is not divisible by the node's cluster "
        "selection, or a forwarded producer result's shape cannot "
        "satisfy the consumer kernel — the dispatch plan could never "
        "build.  Resize the operand, change the selection width, or fix "
        "the forward edge."),
    "OFL007": _CodeInfo(
        "graph width exceeds the in-flight window", Severity.WARNING,
        "More nodes become ready at once than the in-flight window "
        "(policy.window, capped by the runtime's completion-unit "
        "copies) can hold, so issue will stall draining the oldest "
        "in-flight job (InflightWindow.stalls counts these).  Raise "
        "policy.window / n_units, or narrow the graph."),
    "OFL008": _CodeInfo(
        "invalid mode value", Severity.ERROR,
        "A mode field (staging, residency, info_dist, completion, via) "
        "is not a member of its enum — a typo like "
        "info_dist='mulitcast' would otherwise silently misconfigure "
        "the run.  Use the typed enums from repro.api."),
    "OFL009": _CodeInfo(
        "invalid policy field", Severity.ERROR,
        "A numeric or typed policy field is out of range: fuse/window/"
        "depth below 1, RetryPolicy bounds (max_attempts >= 1, "
        "deadline_factor > 1, backoff >= 1), or a field of the wrong "
        "type."),
    "OFL010": _CodeInfo(
        "policy contradiction", Severity.ERROR,
        "Two policy fields cannot hold at once: residency=RESIDENT "
        "stages no operands so a pinned non-DIRECT staging could never "
        "run, and graph submits do not ride the retry/deadline ladder "
        "(policy.retry must be None for submit_graph)."),
    "OFL011": _CodeInfo(
        "inactive lease", Severity.ERROR,
        "The submit targets a lease that is no longer active — it was "
        "released, revoked, or superseded by a resize.  Request a new "
        "lease from the scheduler (or use the current lease object) "
        "before submitting."),
    # -- OFLP1##: performance findings (repro.analysis.perflint) ---------
    "OFLP101": _CodeInfo(
        "suboptimal staging mode", Severity.PERF,
        "The pinned policy.staging moves the replicated operand "
        "footprint over a slower leg than the §6 staging model's best "
        "mode for this byte count and cluster selection (host fan-out "
        "vs. the quadrant fan-out tree).  The finding carries the "
        "predicted cycle delta; apply the suggested staging= pin or "
        "leave the field open so the planner decides.  Note the cycle "
        "model favors the tree from ~4 clusters at any size; on a "
        "cache-dominated host substrate the wallclock crossover sits "
        "near Planner.tree_min_bytes (see the staging_wall bench)."),
    "OFLP102": _CodeInfo(
        "missed fusion opportunity", Severity.PERF,
        "A batched submit pins policy.fuse below the model-optimal "
        "factor: the dispatch-constant phases (A-D, H, I) are paid per "
        "launch and amortize with B, and for this job the host-side "
        "constant dominates the device phases, so a larger fuse "
        "strictly reduces predicted per-job cycles.  Apply the "
        "suggested fuse= or leave it open for the planner."),
    "OFLP103": _CodeInfo(
        "in-flight window below model-optimal", Severity.PERF,
        "policy.window pins the pipeline depth to 1 (or below the "
        "planner's pick) where the amortization model shows an open "
        "window overlapping the next launch's host-side constant with "
        "the current launch's device phases: t_job drops from "
        "t_const/B + t_E + t_F + t_G to max(t_const/B + t_E, t_F + "
        "t_G).  Apply the suggested window= or leave it open."),
    "OFLP104": _CodeInfo(
        "reshard/forward on the critical path", Severity.PERF,
        "A dataflow edge crosses cluster selections, so the consumer "
        "pays a device-to-device forward (DMA setup + transfer + "
        "cross-quadrant hops) on the graph's critical path; aligning "
        "the consumer's selection with its producer forwards by "
        "aliasing at zero modeled cost and lowers the predicted "
        "makespan.  The fix rewrites the consumer node's clusters=."),
    "OFLP105": _CodeInfo(
        "selection breaks single-request multicast", Severity.PERF,
        "The cluster selection is not one aligned power-of-two subcube, "
        "so the one-write wakeup (paper §5) decomposes into multiple "
        "multicast requests — each extra request replays the "
        "dispatch-constant phases.  An aligned window of the same (or "
        "nearest) width dispatches in a single request; the fix "
        "rewrites clusters= to the cheapest single-request window by "
        "predicted total cycles."),
    "OFLP106": _CodeInfo(
        "resident operand never reused", Severity.PERF,
        "Session.stage() paid the staging leg to pin operands resident, "
        "but no later submit redispatched them "
        "(residency=Residency.RESIDENT): the staging cycles and the "
        "device memory are pure waste.  Drop the stage() call, or "
        "redispatch against the warm buffers."),
    "OFLP107": _CodeInfo(
        "donation disabled on a dead buffer", Severity.PERF,
        "A fused batch launch stages fresh host operands whose stacked "
        "device buffers die at launch, and an operand matches the "
        "output shape — with donate_operands=False XLA must allocate "
        "and fill a fresh output buffer per launch instead of aliasing "
        "the dead operand in place.  Pin donate_operands=True (safe: "
        "fresh-staged buffers have no other readers) to save one "
        "buffer copy per launch and halve peak device memory."),
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One typed finding of the static verifier (or a runtime shim).

    ``code`` indexes :data:`CODES`; ``node``/``name`` locate the
    offending graph node (index and, when it has one, its
    ``GraphNode.name`` — or the offending policy/operand field);
    ``suggestion`` is the actionable fix (defaulted from the code
    table's explain text when left empty).
    """

    code: str
    message: str
    severity: Severity = Severity.ERROR
    node: Optional[int] = None
    name: Optional[str] = None
    suggestion: str = ""

    def __post_init__(self) -> None:
        if self.code not in CODES:
            raise ValueError(f"unknown diagnostic code {self.code!r} "
                             f"(known: {sorted(CODES)})")
        info = CODES[self.code]
        object.__setattr__(self, "severity", Severity(self.severity))
        if not self.suggestion:
            object.__setattr__(self, "suggestion", info.explain)

    @property
    def title(self) -> str:
        return CODES[self.code].title

    def __str__(self) -> str:
        where = ""
        if self.node is not None:
            where = f" [node {self.node}" + (
                f" ({self.name})]" if self.name else "]")
        elif self.name is not None:
            where = f" [{self.name}]"
        return f"{self.code}: {self.message}{where}"

    def to_json(self) -> str:
        """Stable JSON serialization (round-trips via :meth:`from_json`)."""
        return json.dumps({
            "code": self.code,
            "title": self.title,
            "severity": self.severity.value,
            "message": self.message,
            "node": self.node,
            "name": self.name,
            "suggestion": self.suggestion,
        }, sort_keys=True)

    @classmethod
    def from_json(cls, payload: str) -> "Diagnostic":
        d: Mapping[str, Any] = json.loads(payload)
        return cls(code=d["code"], message=d["message"],
                   severity=Severity(d["severity"]), node=d.get("node"),
                   name=d.get("name"), suggestion=d.get("suggestion", ""))

    def as_error(self, cls: Type[Exception] = ValueError) -> Exception:
        """This diagnostic as a raisable exception of type ``cls``.

        The legacy-exception shims use this: the raised error keeps its
        historical type (so existing ``except`` clauses keep working)
        while carrying ``.code`` and ``.diagnostic`` for new callers.
        """
        err = cls(str(self))
        err.code = self.code                 # type: ignore[attr-defined]
        err.diagnostic = self                # type: ignore[attr-defined]
        return err


class UnknownDiagnosticCode(KeyError):
    """``explain()`` was asked about a code the table does not know.

    Subclasses :class:`KeyError` (the historical behavior) but carries
    the offending ``.code`` and a nearest-known-code ``.suggestion``
    so CLIs and error surfaces can answer "did you mean OFLP101?"
    instead of a bare traceback.
    """

    def __init__(self, code: str):
        self.code = code
        matches = difflib.get_close_matches(
            str(code).upper(), sorted(CODES), n=1, cutoff=0.4)
        self.suggestion: Optional[str] = matches[0] if matches else None
        hint = f" — did you mean {self.suggestion!r}?" if self.suggestion \
            else ""
        super().__init__(f"unknown diagnostic code {code!r}{hint} "
                         f"(known: {sorted(CODES)})")

    def __str__(self) -> str:
        # KeyError.__str__ repr()s its single arg; keep the message flat.
        return str(self.args[0])


def explain(code: str) -> str:
    """Long-form explanation of a diagnostic code (``OFL001``...).

    Raises :class:`UnknownDiagnosticCode` (a :class:`KeyError`) with a
    nearest-code suggestion when the code is not in the table.
    """
    info = CODES.get(code)
    if info is None:
        raise UnknownDiagnosticCode(code)
    return f"{code} [{info.severity.value}] {info.title}: {info.explain}"


class DiagnosticsLog:
    """Bounded in-memory diagnostics table for long-lived sessions.

    The verifier and the perf linter report findings on *every* submit;
    a serve loop that runs for days would grow an append-only list
    without bound.  This is the fix: a ring buffer of the most recent
    ``limit`` diagnostics plus counters that never lose information —
    ``total`` counts every record ever made and ``dropped`` how many
    fell off the front (``total - len(log)``).

    ``limit <= 0`` disables retention entirely (counters still tick).
    """

    def __init__(self, limit: int = 256):
        self.limit = int(limit)
        self._buf: Deque[Diagnostic] = collections.deque(
            maxlen=max(0, self.limit))
        self.total = 0

    @property
    def dropped(self) -> int:
        """Diagnostics that fell off the front of the ring."""
        return self.total - len(self._buf)

    def record(self, diags: Iterable[Diagnostic]) -> None:
        for d in diags:
            self.total += 1
            if self.limit > 0:
                self._buf.append(d)

    def snapshot(self) -> List[Diagnostic]:
        """The retained diagnostics, oldest first (a copy)."""
        return list(self._buf)

    def counts(self) -> Dict[str, int]:
        """Retained diagnostics histogrammed by code."""
        out: Dict[str, int] = {}
        for d in self._buf:
            out[d.code] = out.get(d.code, 0) + 1
        return out

    def clear(self) -> None:
        self._buf.clear()
        self.total = 0

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self) -> Iterator[Diagnostic]:
        return iter(self._buf)

    def __repr__(self) -> str:
        return (f"DiagnosticsLog(limit={self.limit}, retained={len(self)}, "
                f"total={self.total}, dropped={self.dropped})")


# -- shim constructors (the core modules raise through these) ----------------


def invalid_mode(field: str, value: Any,
                 valid: Tuple[str, ...]) -> Diagnostic:
    """OFL008: an enum-valued mode field rejected a value."""
    return Diagnostic("OFL008", f"{field} {value!r} not in {valid}",
                      name=field)


def invalid_field(field: str, message: str) -> Diagnostic:
    """OFL009: a policy field failed its range/type validation."""
    return Diagnostic("OFL009", message, name=field)


def contradiction(message: str, name: Optional[str] = None) -> Diagnostic:
    """OFL010: two policy fields cannot hold at once."""
    return Diagnostic("OFL010", message, name=name)


def use_after_donate(what: str, node: Optional[int] = None,
                     name: Optional[str] = None) -> Diagnostic:
    """OFL003: a donated (deleted) device buffer would be read."""
    return Diagnostic(
        "OFL003", f"{what} was deleted by a donating dispatch",
        node=node, name=name,
        suggestion=(
            "restage it from the host copy (plan.resident_operands "
            "restores resident buffers automatically) or disable "
            "donate_operands for buffers that must stay readable"))
