"""Dependent job graphs, statically verified before dispatch.

Builds the two canonical graph shapes (a K=8 self-scaling chain and a
diamond whose arms run on disjoint cluster windows), runs them through
``verify_graph`` — zero diagnostics — then submits them and shows the
scoreboarded out-of-order dispatch path: 0 intermediate d2h bytes, one
device-to-device forward per edge.  Finally it seeds a defect (a
dependency cycle) and shows the submit gate rejecting it *before* any
staging, with a stable ``OFL001`` diagnostic.

    PYTHONPATH=src python examples/job_graph.py

The graph builders are imported by ``make verify-graphs`` (the
zero-diagnostics gate over every checked-in graph), so they construct
nodes without touching devices.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

from repro.api import GraphNode, Ref, Session, VerificationError, verify_graph
from repro.core import jobs

CHAIN_K = 8
N = 2048


def build_chain(K: int = CHAIN_K):
    """y ← 2.5·x + y repeated K times, each link reading the previous
    node's result through a d2d-forwarded ``Ref``."""
    job = jobs.make_axpy(N)
    ops, _ = job.make_instance(0)
    ops = {k: np.asarray(v, dtype=np.float64) for k, v in ops.items()}
    nodes = [GraphNode(job, ops, name="n0")]
    for k in range(1, K):
        nodes.append(GraphNode(job, {"x": ops["x"], "y": Ref(f"n{k-1}")},
                               name=f"n{k}"))
    return nodes


def build_diamond():
    """src fans out to two half-mesh arms that rejoin."""
    job = jobs.make_axpy(N)
    ops, _ = job.make_instance(1)
    ops = {k: np.asarray(v, dtype=np.float64) for k, v in ops.items()}
    return [
        GraphNode(job, ops, name="src"),
        GraphNode(job, {"x": ops["x"], "y": Ref("src")}, name="l",
                  clusters=[0, 1, 2, 3]),
        GraphNode(job, {"x": ops["x"], "y": Ref("src")}, name="r",
                  clusters=[4, 5, 6, 7]),
        GraphNode(job, {"x": Ref("l"), "y": Ref("r")}, name="join"),
    ]


def build_reshard():
    """A serial wide -> narrow -> wide chain: both edges pay a d2d
    reshard forward on the critical path.  Deliberate — it is the perf
    linter's worked example (section 5 of ``main``), so the file-level
    allow below keeps its ``OFLP104`` findings out of ``make
    lint-graphs`` while ``python -m repro.lint`` still reports them."""
    # repro: allow(OFLP104) -- intentional reshard, demonstrated in main()
    job = jobs.make_axpy(N)
    ops, _ = job.make_instance(3)
    ops = {k: np.asarray(v, dtype=np.float64) for k, v in ops.items()}
    return [
        GraphNode(job, ops, name="wide"),
        GraphNode(job, {"x": ops["x"], "y": Ref("wide")}, name="narrow",
                  clusters=[0, 1, 2, 3]),
        GraphNode(job, {"x": ops["x"], "y": Ref("narrow")}, name="tail"),
    ]


def build_graphs():
    """name -> GraphNode list, for the ``make verify-graphs`` gate."""
    return {"chain": build_chain(), "diamond": build_diamond(),
            "reshard": build_reshard()}


def main() -> None:
    print("=== 1. static verification: both graphs come back clean ===")
    for name, nodes in build_graphs().items():
        diags = verify_graph(nodes, default_width=8)
        print(f"  {name}: {len(nodes)} nodes -> {len(diags)} diagnostics")
        assert not diags

    print("\n=== 2. the chain: forwarded results, 0 intermediate d2h ===")
    sess = Session()
    nodes = build_chain()
    out = sess.submit_graph(nodes).wait()
    final = out[f"n{CHAIN_K - 1}"]
    print(f"  forwards={sess.stats.forwards} (one per edge), "
          f"intermediate d2h bytes="
          f"{sess.stats.d2h_bytes - final.nbytes}")

    seq = np.asarray(nodes[0].operands["y"], dtype=np.float64)
    x = np.asarray(nodes[0].operands["x"], dtype=np.float64)
    for _ in range(CHAIN_K):
        seq = 2.5 * x + seq
    print(f"  allclose vs sequential numpy: "
          f"{np.allclose(np.asarray(final), seq)}")

    print("\n=== 3. the diamond: both arms in flight concurrently ===")
    gh = sess.submit_graph(build_diamond())
    gh.wait()
    print(f"  max_inflight={gh.max_inflight} (>= 2: arms overlapped)")

    print("\n=== 4. perf lint: the reshard chain leaves cycles on "
          "the table ===")
    from repro.analysis import perflint
    nodes = build_reshard()
    findings = perflint.lint_graph(nodes, default_width=8)
    for f in findings:
        print(f"  {f}")
    fixed = perflint.apply(findings, nodes=nodes).nodes
    out_a = sess.submit_graph(nodes).wait()
    out_b = sess.submit_graph(fixed).wait()
    same = all(np.array_equal(np.asarray(out_a[k]), np.asarray(out_b[k]))
               for k in out_a)
    print(f"  autofixed graph bit-identical: {same}")

    print("\n=== 5. a seeded defect is rejected before any staging ===")
    job = jobs.make_axpy(N)
    ops, _ = job.make_instance(2)
    bad = [GraphNode(job, {"x": ops["x"], "y": Ref("b")}, name="a"),
           GraphNode(job, {"x": ops["x"], "y": Ref("a")}, name="b")]
    try:
        sess.submit_graph(bad)
    except VerificationError as e:
        print(f"  codes={e.codes}")
        for d in e.diagnostics:
            print(f"  {d}")


if __name__ == "__main__":
    main()
