"""End-to-end training driver example: a ~few-hundred-step run of the
(reduced) SmolLM config on a 4×2 CPU mesh, with checkpointing, a simulated
mid-run failure, and a bit-exact elastic resume on fewer devices.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(For the full-size architectures use repro.launch.train with --arch; the
100M-scale end-to-end budget on CPU is covered by --reduced configs.)
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro import models as M
from repro.checkpoint import restore, save
from repro.data import DataConfig, SyntheticStream
from repro.dist.sharding import to_shardings
from repro.ft.elastic import elastic_restore
from repro.optim.adamw import adamw_init
from repro.train import TrainConfig, build_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--fail-at", type=int, default=120)
    args = ap.parse_args()

    cfg = M.reduced(M.get("smollm-360m"), n_layers=4, d_model=256,
                    n_heads=8, n_kv_heads=4, head_dim=32, d_ff=512,
                    vocab_size=4096)
    devs = jax.devices()
    mesh = Mesh(np.array(devs).reshape(4, 2), ("data", "model"))
    print(f"arch={cfg.name} params={M.count_params(cfg)/1e6:.1f}M "
          f"mesh={mesh.devices.shape} {mesh.axis_names}")

    stream = SyntheticStream(
        DataConfig(vocab_size=cfg.vocab_size, batch_size=8, seq_len=64,
                   seed=0), cfg)
    bs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
          for k, v in stream.batch(0).items()}
    tcfg = TrainConfig(base_lr=1e-3, warmup_steps=20, total_steps=args.steps,
                       microbatches=2)
    step_fn, pspecs, ospecs, bspecs = build_train_step(cfg, mesh, tcfg, bs)
    params = jax.device_put(M.init_params(jax.random.key(0), cfg),
                            to_shardings(pspecs, mesh))
    opt = jax.device_put(adamw_init(params), to_shardings(ospecs, mesh))

    ckdir = tempfile.mkdtemp(prefix="trainlm_")
    first_loss = None
    failed_once = False
    i = 0
    while i < args.steps:
        batch = jax.device_put(stream.batch(i), to_shardings(bspecs, mesh))
        params, opt, m = step_fn(params, opt, batch, jnp.asarray(i))
        if first_loss is None:
            first_loss = float(m["loss"])
        if i % 25 == 0:
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.2f}")
        if (i + 1) % 20 == 0:
            save(ckdir, i + 1, {"params": params, "opt": opt},
                 {"params": pspecs, "opt": ospecs}, data_index=i + 1)
        i += 1
        if i == args.fail_at and not failed_once:
            failed_once = True
            print(f"--- simulated node failure at step {i}; "
                  f"elastic restart on 4 devices ---")
            ks = jax.eval_shape(lambda: jax.random.key(0))
            pshapes = jax.eval_shape(
                lambda k: M.init_params(k, cfg),
                jax.ShapeDtypeStruct(ks.shape, ks.dtype))
            st, di, state, mesh = elastic_restore(ckdir, devs[:4], pshapes)
            step_fn, pspecs, ospecs, bspecs = build_train_step(
                cfg, mesh, tcfg, bs)
            params, opt = state["params"], state["opt"]
            i = di
            print(f"--- resumed from step {di} on mesh {mesh.devices.shape} ---")

    final = float(m["loss"])
    print(f"\nfirst loss {first_loss:.3f} -> final {final:.3f} "
          f"(dropped {first_loss - final:.3f} nats over {args.steps} steps)")


if __name__ == "__main__":
    main()
