"""Batched serving example: prefill + decode with KV/SSM caches for three
different architecture families (dense GQA, MLA, hybrid SSM), driven by the
ServeEngine with completion-unit tracking per step.

    PYTHONPATH=src python examples/serve_batch.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np
from jax.sharding import Mesh

from repro import models as M
from repro.api import ServeConfig, ServeEngine, Staging
from repro.data import DataConfig, SyntheticStream


def demo(arch: str, batch: int = 4, prompt: int = 16, new: int = 24) -> None:
    cfg = M.reduced(M.get(arch))
    devs = jax.devices()
    mesh = Mesh(np.array(devs).reshape(4, 2), ("data", "model"))
    params = jax.device_get(M.init_params(jax.random.key(0), cfg))

    engine = ServeEngine(cfg, params, mesh,
                         ServeConfig(batch=batch, max_len=prompt + new + 1,
                                     temperature=0.8, seed=7,
                                     staging=Staging.TREE))
    # typed tree staging: replicated weight leaves cross the host link
    # once and fan out device-to-device (stats below count the bytes)
    engine.place_params(params)
    stream = SyntheticStream(
        DataConfig(vocab_size=cfg.vocab_size, batch_size=batch,
                   seq_len=prompt, seed=1), cfg)
    ex = stream.batch(0)
    extra = {k: v for k, v in ex.items() if k == "patches"}
    t0 = time.time()
    out = engine.generate(ex["tokens"], new, extra or None)
    dt = time.time() - t0
    cache_kind = ("compressed-KV (MLA)" if cfg.mla
                  else "SSM state" if cfg.ssm else "KV")
    print(f"{arch:24s} [{cfg.family:6s}] cache={cache_kind:20s} "
          f"{batch * new} tokens in {dt:5.1f}s ({batch * new / dt:6.1f} tok/s)")
    print(f"  weight placement: {engine.stats['h2d_bytes'] / 1e6:.1f} MB "
          f"host-link, {engine.stats['d2d_bytes'] / 1e6:.1f} MB d2d "
          f"(staging={engine.scfg.staging.value})")
    print(f"  sample: {out[0][:12].tolist()}")


def main() -> None:
    for arch in ("smollm-360m", "deepseek-v2-lite-16b", "zamba2-2.7b"):
        demo(arch)


if __name__ == "__main__":
    main()
