"""Analytical-model walkthrough: reproduce the paper's eqs. 5/6 and fig. 12
validation, then use the model the way the paper intends — to make offload
decisions, including the session API's ``policy=AUTO`` mode selection.

    PYTHONPATH=src python examples/offload_model_validation.py
"""

from repro.api import AUTO, estimate
from repro.core import jobs, model, simulator


def main() -> None:
    print("=== eq. 5 (paper, verbatim) vs our structural model ===")
    print(f"{'N':>6} {'n':>3} {'eq.5':>10} {'structural':>10} {'simulated':>10}")
    for N in (256, 1024, 4096):
        for n in (1, 8, 32):
            eq5 = model.axpy_closed_form(n, N)
            ours = model.predict_total(jobs.axpy_spec(N), n)
            sim = simulator.simulate(jobs.axpy_spec(N), n, "multicast").total
            print(f"{N:6d} {n:3d} {eq5:10.1f} {ours:10.1f} {sim:10.1f}")

    print("\n=== fig. 12: model error across kernels (paper: <15 %) ===")
    cases = {
        "axpy": (jobs.axpy_spec, [(64,), (256,), (1024,)]),
        "atax": (jobs.atax_spec, [(32, 32), (128, 128)]),
        "matmul": (lambda s: jobs.matmul_spec(s, s, s), [(16,), (64,)]),
        "covariance": (lambda s: jobs.covariance_spec(s, 2 * s), [(32,)]),
        "montecarlo": (jobs.montecarlo_spec, [(16384,)]),
        "bfs": (jobs.bfs_spec, [(256,)]),
    }
    for name, (mk, sizes) in cases.items():
        v1 = model.max_rel_error(model.validate(mk, sizes, (1, 2, 4, 8, 16, 32)))
        v2 = model.max_rel_error(model.validate(
            mk, sizes, (1, 2, 4, 8, 16, 32), predictor=model.predict_total_v2))
        print(f"  {name:12s} eq.4 model: {v1*100:5.2f}%   "
              f"+port-bound (ours): {v2*100:5.2f}%")

    print("\n=== the offload decision (paper §1: 'if' and 'how') ===")
    for name, mk in (("axpy-256", lambda: jobs.axpy_spec(256)),
                     ("axpy-65536", lambda: jobs.axpy_spec(65536)),
                     ("atax-64", lambda: jobs.atax_spec(64, 64))):
        n, t = model.optimal_clusters(mk)
        host = 3.0 * model.predict_total(mk(), 1)   # pretend host is 3x slower
        go, n2, t2 = model.should_offload(mk(), host)
        print(f"  {name:12s}: offload to n={n:2d} (predicted {t:8.0f} cyc); "
              f"vs host {host:8.0f} cyc -> offload={go}")

    print("\n=== Session.estimate: the model as an API contract (AUTO) ===")
    for name, mkjob in (("axpy-16k", lambda: jobs.make_axpy(16384)),
                        ("matmul-256", lambda: jobs.make_matmul(256, 256, 256)),
                        ("covariance-64", lambda: jobs.make_covariance(64, 128))):
        est = estimate(mkjob(), n=8, batch=16, policy=AUTO)
        d = est.decision
        sim = simulator.simulate(mkjob().spec, 8, "multicast").total
        err = simulator.model_error(est.job_cycles, sim)
        print(f"  {name:14s}: fuse={d.fuse} window={d.window} "
              f"staging={d.staging.value:7s}  predicted {est.job_cycles:9.0f} "
              f"cyc (sim {sim:9.0f}, err {err * 100:4.1f}%)")


if __name__ == "__main__":
    main()
