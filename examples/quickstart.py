"""Quickstart: the paper's offload runtime in two minutes.

Drives the paper's AXPY kernel through the session API (typed policies,
one submit path, ``policy=AUTO`` model-driven mode selection), compares
both offload implementations' collective structure, and asks the
analytical model for the optimal offload width.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_ENABLE_X64", "true")

import numpy as np

from repro.api import (
    AUTO, InfoDist, OffloadPolicy, Residency, Session,
)
from repro.core import jobs, model, simulator
from repro.core.multicast import CLUSTER_OFFSET_BITS, MulticastRequest
from repro.core.offload import count_collectives


def main() -> None:
    job = jobs.make_axpy(4096)
    sess = Session()          # every local "cluster"; default policy=AUTO

    print("=== 1. one submit path, both implementations (8 clusters) ===")
    for label, pol in (("baseline ", OffloadPolicy(
                            info_dist=InfoDist.P2P_CHAIN,
                            completion="central_counter")),
                       ("multicast", AUTO)):
        operands, expected = job.make_instance(0)
        got = sess.submit(job, operands, n=8, policy=pol).wait()
        colls = count_collectives(sess.runtime(pol).lowered_text(job, 8))
        print(f"  {label}: allclose={np.allclose(got, expected)}  "
              f"chain={colls['collective-permute']} collective-permutes, "
              f"{colls['all-reduce']} all-reduce")

    print("\n=== 2. AUTO: fused + pipelined + resident, planner-picked ===")
    instances, exps = jobs.make_instances(job, 16, seed0=2)
    handle = sess.submit(job, instances, n=8)     # policy=AUTO
    results = handle.wait()
    ok = all(np.allclose(r, e) for r, e in zip(results, exps))
    d = handle.decision
    print(f"  16 jobs -> fuse={d.fuse}, window={d.window}, "
          f"staging={d.staging.value}; allclose={ok}")
    print("  predicted vs measured (handle.explain()):")
    for line in str(handle.explain()).splitlines():
        print(f"    {line}")
    sess.stage(job, instances[0], n=8)            # prime residency
    got = sess.submit(job, Residency.RESIDENT, n=8).wait()
    print(f"  resident redispatch: allclose={np.allclose(got, exps[0])}")

    print("\n=== 3. cluster selection via the paper's address-mask (fig. 5) ===")
    req = MulticastRequest(addr=1 << CLUSTER_OFFSET_BITS,
                           mask=0b110 << CLUSTER_OFFSET_BITS)
    devs, ids = sess.runtime().select_clusters(request=req)
    operands, expected = job.make_instance(1)
    got = sess.submit(job, operands, request=req).wait()
    print(f"  mask 0b110 over cluster bits -> clusters {ids}; "
          f"allclose={np.allclose(got, expected)}")

    print("\n=== 4. the simulator: what this offload costs on Occamy ===")
    for n in (1, 4, 8, 32):
        base = simulator.simulate(job.spec, n, 'baseline').total
        ext = simulator.simulate(job.spec, n, 'multicast').total
        print(f"  n={n:2d}: baseline={base:7.0f} cyc  multicast={ext:7.0f} cyc "
              f"  speedup={base/ext:.2f}x")

    print("\n=== 5. the analytical model: how wide should we offload? ===")
    for N in (64, 1024, 65536):
        n_opt, t = model.optimal_clusters(lambda: jobs.axpy_spec(N))
        print(f"  AXPY N={N:6d}: optimal n={n_opt:2d} "
              f"(predicted {t:.0f} cycles; eq.5 t̂=400+N/4+2.47N/8n)")


if __name__ == "__main__":
    main()
