"""Quickstart: the paper's offload runtime in two minutes.

Offloads the paper's AXPY kernel onto an 8-"cluster" mesh through both
offload implementations, shows the O(n)-chain vs broadcast-tree collective
structure, and asks the analytical model for the optimal offload width.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_ENABLE_X64", "true")

import numpy as np

from repro.core import jobs, model, simulator
from repro.core.multicast import CLUSTER_OFFSET_BITS, MulticastRequest
from repro.core.offload import OffloadConfig, OffloadRuntime, count_collectives


def main() -> None:
    job = jobs.make_axpy(4096)

    print("=== 1. offload through both implementations (8 clusters) ===")
    for label, cfg in (("baseline ", OffloadConfig.baseline()),
                       ("multicast", OffloadConfig.extended())):
        rt = OffloadRuntime(config=cfg)
        got, expected = rt.run(job, seed=0, n=8)
        colls = count_collectives(rt.lowered_text(job, 8))
        print(f"  {label}: allclose={np.allclose(got, expected)}  "
              f"chain={colls['collective-permute']} collective-permutes, "
              f"{colls['all-reduce']} all-reduce")

    print("\n=== 2. cluster selection via the paper's address-mask (fig. 5) ===")
    req = MulticastRequest(addr=1 << CLUSTER_OFFSET_BITS,
                           mask=0b110 << CLUSTER_OFFSET_BITS)
    rt = OffloadRuntime(config=OffloadConfig.extended())
    devs, ids = rt.select_clusters(request=req)
    got, expected = rt.run(job, seed=1, request=req)
    print(f"  mask 0b110 over cluster bits -> clusters {ids}; "
          f"allclose={np.allclose(got, expected)}")

    print("\n=== 3. the simulator: what this offload costs on Occamy ===")
    for n in (1, 4, 8, 32):
        base = simulator.simulate(job.spec, n, 'baseline').total
        ext = simulator.simulate(job.spec, n, 'multicast').total
        print(f"  n={n:2d}: baseline={base:7.0f} cyc  multicast={ext:7.0f} cyc "
              f"  speedup={base/ext:.2f}x")

    print("\n=== 4. the analytical model: how wide should we offload? ===")
    for N in (64, 1024, 65536):
        n_opt, t = model.optimal_clusters(lambda: jobs.axpy_spec(N))
        print(f"  AXPY N={N:6d}: optimal n={n_opt:2d} "
              f"(predicted {t:.0f} cycles; eq.5 t̂=400+N/4+2.47N/8n)")


if __name__ == "__main__":
    main()
