PYTHON ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: check test typecheck bench-smoke bench-offload verify-graphs lint-graphs

# Tier-1 verify: full test suite + a benchmark smoke (what CI runs).
check: test typecheck bench-smoke verify-graphs lint-graphs

test:
	$(PYTHON) -m pytest -x -q

# Static types on the public surface (repro.api, all of repro.core, the
# analysis package, the serve engine, and the fault-tolerance
# substrate).  Skips gracefully where mypy is not installed (it is in
# requirements-dev.txt, so CI always runs it).
typecheck:
	@if $(PYTHON) -c "import mypy" 2>/dev/null; then \
		$(PYTHON) -m mypy --config-file mypy.ini \
			src/repro/api.py src/repro/lint.py src/repro/core/ \
			src/repro/analysis/ \
			src/repro/serve/engine.py src/repro/ft/; \
	else \
		echo "mypy not installed; skipping typecheck"; \
	fi

# Zero-diagnostics gate: every checked-in job graph (examples/ +
# benchmarks/) must pass the static verifier with no diagnostics.
verify-graphs:
	$(PYTHON) benchmarks/verify_graphs.py

# Zero-new-findings perf gate: the same graphs through the perf linter;
# `# repro: allow(...)` comments and LINT_baseline.json absorb the
# accepted debt, anything else fails (python -m repro.lint --help).
lint-graphs:
	$(PYTHON) -m repro.lint

bench-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
		$(PYTHON) -m benchmarks.run --only fig07,fig12,staging,session,scheduler,faults,preempt,dag,perflint --check BENCH_offload.json

# The tracked dispatch-overhead trajectory (writes BENCH_offload.json).
bench-offload:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
		$(PYTHON) -m benchmarks.run \
			--only offload,stream,serve_stream,staging,staging_wall,session,scheduler,faults,preempt,dag,perflint,fig07,fig09,fig12 \
			--json BENCH_offload.json
