PYTHON ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: check test bench-smoke bench-offload

# Tier-1 verify: full test suite + a benchmark smoke (what CI runs).
check: test bench-smoke

test:
	$(PYTHON) -m pytest -x -q

bench-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
		$(PYTHON) -m benchmarks.run --only fig07,fig12,staging,session --check BENCH_offload.json

# The tracked dispatch-overhead trajectory (writes BENCH_offload.json).
bench-offload:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
		$(PYTHON) -m benchmarks.run \
			--only offload,stream,serve_stream,staging,staging_wall,session,fig07,fig09,fig12 \
			--json BENCH_offload.json
