"""Fault-recovery contract — deterministic, part of the CI subset.

Three claims of the ISSUE-6 fault-tolerance substrate (`repro.core.
faults` + the session's reliable submit path), pinned numerically:

* **bit-identical recovery** — under the default :class:`RetryPolicy`,
  every recoverable fault scenario (transient lost arrival, straggler
  past the deadline, dead cluster inside the selection) returns results
  bit-equal to the fault-free run.  The suite asserts this itself, so a
  recovery regression fails the run even before ``--check`` compares
  rows.

* **recovery overhead** — the extra virtual cycles each scenario costs
  over the fault-free baseline, recorded per scenario together with the
  exact escalation counters (deadline trips, retries, probes, backups).
  The timeline is model arithmetic on the injector's deterministic
  schedule — no wallclock, so the rows are exact-compare stable.

* **recovery model** — :func:`predict_recovery`'s closed form predicts
  the measured overhead within the paper's §6 accuracy bar; the
  ``model_error`` rows feed the harness's hard <15 % check.

Needs the 8-device XLA host platform (the bench-smoke XLA_FLAGS);
everything else is deterministic model arithmetic.
"""

from __future__ import annotations

from typing import List, Tuple

from repro.core import jobs
from repro.core.faults import (
    FaultInjector,
    FaultKind,
    FaultPlan,
    FaultSpec,
    predict_recovery,
)
from repro.core.policy import OffloadPolicy, RetryPolicy
from repro.core.session import Session

Row = Tuple[str, float, str]

#: selection size for every scenario (half the 8-cluster test substrate)
N = 4

RETRY = RetryPolicy()        # the default ladder: 3 attempts, 3x deadline

#: one scenario per recoverable fault class, each a single-fault plan so
#: the per-scenario overhead row isolates that class's recovery cost
SCENARIOS = (
    ("lost_arrival",
     FaultPlan([FaultSpec(FaultKind.LOST_ARRIVAL, at_dispatch=0, count=1)])),
    ("straggle",
     FaultPlan([FaultSpec(FaultKind.STRAGGLE, at_dispatch=0, factor=10.0)])),
    ("cluster_death",
     FaultPlan([FaultSpec(FaultKind.CLUSTER_DEATH, at_dispatch=0,
                          clusters=(1,))])),
)


def faults_suite() -> Tuple[List[Row], str]:
    import numpy as np

    job = jobs.make_axpy(512)
    operands, _ = job.make_instance(0)
    pol = OffloadPolicy(retry=RETRY)

    # fault-free baseline: the reliable path's virtual timeline with no
    # injector is exactly the §6 job estimate
    clean = Session(policy=pol)
    ref = np.asarray(clean.submit(job, dict(operands), n=N).wait())
    base = clean.health().virtual_cycles
    clean.close()

    rows: List[Row] = [("faults/fault_free/cycles", base, "cycles")]
    errs: List[float] = []
    for name, plan in SCENARIOS:
        sess = Session(policy=pol, faults=FaultInjector(plan))
        out = np.asarray(sess.submit(job, dict(operands), n=N).wait())
        h = sess.health()
        sess.close()

        bitexact = 1.0 if np.array_equal(out, ref) else 0.0
        assert bitexact == 1.0, (
            f"recovery under {name!r} is not bit-identical to the "
            "fault-free run")
        measured = h.virtual_cycles - base
        predicted = predict_recovery(job, N, plan, RETRY)
        err = abs(predicted - measured) / measured * 100.0
        errs.append(err)
        rows += [
            (f"faults/{name}/overhead", measured, "cycles"),
            (f"faults/{name}/predicted", predicted, "cycles"),
            (f"faults/{name}/model_error", err, "percent"),
            (f"faults/{name}/bitexact", bitexact, "count"),
            (f"faults/{name}/deadline_trips", float(h.deadline_trips),
             "count"),
            (f"faults/{name}/retries", float(h.retries), "count"),
            (f"faults/{name}/probes", float(h.probes), "count"),
            (f"faults/{name}/backups", float(h.backups), "count"),
        ]

    derived = (
        f"all {len(SCENARIOS)} recoverable scenarios bit-identical under "
        f"the default RetryPolicy; recovery-model error max "
        f"{max(errs):.2f}% (paper bar <15%)")
    return rows, derived
