"""``make verify-graphs`` — zero-diagnostics gate over checked-in graphs.

Collects every job graph the repo ships — the example graphs
(``examples/job_graph.py:build_graphs``) and the real-mesh benchmark
graphs (``benchmarks/dag_bench.py:bench_graphs``) — and runs the static
verifier over each.  Any diagnostic (including warnings) fails the
gate: checked-in graphs are documentation, and documentation with
latent hazards teaches the hazard.

    PYTHONPATH=src python benchmarks/verify_graphs.py

Exit status: 0 when every graph verifies clean, 1 otherwise.
"""

from __future__ import annotations

import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
for sub in ("examples", "benchmarks"):
    p = str(_ROOT / sub)
    if p not in sys.path:
        sys.path.insert(0, p)

#: mesh width the CI bench mesh uses; sharded-divisibility checks run
#: against it even though verification itself never touches a device
MESH_WIDTH = 8


def collect() -> dict:
    """name -> GraphNode list from every registered graph source."""
    import dag_bench
    import job_graph

    graphs: dict = {}
    for source, builder in (("examples/job_graph", job_graph.build_graphs),
                            ("benchmarks/dag_bench", dag_bench.bench_graphs)):
        for name, nodes in builder().items():
            graphs[f"{source}:{name}"] = nodes
    return graphs


def main() -> int:
    from repro.analysis import verify_graph

    graphs = collect()
    failed = 0
    for name, nodes in sorted(graphs.items()):
        diags = verify_graph(nodes, default_width=MESH_WIDTH)
        status = "ok" if not diags else f"{len(diags)} diagnostic(s)"
        print(f"  {name:45s} {len(nodes):3d} nodes  {status}")
        for d in diags:
            print(f"    {d}")
        failed += bool(diags)
    total = len(graphs)
    print(f"verify-graphs: {total - failed}/{total} graphs clean")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
