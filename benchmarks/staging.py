"""Staging-cost model validation — the §6 treatment applied to phase-E
hierarchical broadcast staging (deterministic; part of the CI subset).

For each operand size × cluster count × staging strategy the suite records
the discrete-event staging time (``simulate_staging``: per-edge setup,
quadrant-dependent wire latencies, host-link issue serialization), the
closed-form prediction (``staging_model``: the eq.-5-style linear model the
README documents), and their relative error — the paper's <15 % bar is
enforced on every ``model_error`` row by ``benchmarks/run.py --check``.

The O(n) -> O(log n) claim falls out of the same rows: the host-fan-out /
tree cycle ratio at n=32 is the derived headline.  Real-runtime staging
wallclock lives in the ``staging_wall`` suite
(``benchmarks/offload_wallclock.py``); this suite is the model's
deterministic anchor, so benchmark bit-rot breaks the build rather than
drifting silently.
"""

from __future__ import annotations

from typing import List, Tuple

from repro.core import simulator

Row = Tuple[str, float, str]

#: operand sizes (KiB) in the link-bound regime the closed form targets —
#: below ~2 KiB the host's outstanding-write budget (host_store_next)
#: dominates and the linear model degrades past the bar (documented in the
#: README's model notes), so the recorded sweep starts at 4 KiB
SIZES_KIB = (4, 64, 1024)
NS = (1, 2, 4, 8, 16, 32)


def staging_suite() -> Tuple[List[Row], str]:
    rows: List[Row] = []
    errs: List[float] = []
    for kib in SIZES_KIB:
        nbytes = kib * 1024
        for mode in simulator.STAGING_MODES:
            for n in NS:
                de = simulator.simulate_staging(nbytes, n, mode)
                cf = simulator.staging_model(nbytes, n, mode)
                err = simulator.model_error(cf, de)
                errs.append(err)
                rows.append(
                    (f"staging/{kib}KiB/{mode}/n={n}", de, "cycles"))
                rows.append((f"staging/{kib}KiB/{mode}/n={n}/model_error",
                             err * 100, "percent"))
    nb = 64 * 1024
    ratio32 = (simulator.simulate_staging(nb, 32, "host_fanout")
               / simulator.simulate_staging(nb, 32, "tree"))
    depth32 = simulator.staging_model(nb, 32, "tree")
    rows.append(("staging/64KiB/hf_over_tree/n=32", ratio32, "speedup"))
    derived = (
        f"max model error {max(errs)*100:.1f}% over "
        f"{len(errs)} points (paper bar <15%); host-fanout/tree cycle "
        f"ratio {ratio32:.2f}x at n=32, 64KiB (O(n) link vs O(1) link + "
        f"O(log n) hops; tree closed form {depth32:.0f} cyc)")
    return rows, derived
