"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--list] [--only fig07,...] \\
        [--json BENCH_offload.json] [--check BENCH_offload.json]

Prints ``name,us_per_call,derived`` CSV.  Simulator-backed figures report
modeled cycles (1 cycle = 1 ns at the paper's 1 GHz testbench); `derived`
carries each figure's headline statistic next to the paper's published
value.  ``--list`` prints every suite with its one-line description and
which CI gate covers it; an unknown ``--only`` name is an error (it used
to silently run nothing).

``--json PATH`` additionally writes the run as structured JSON — one entry
per suite with its rows, the derived headline, and (where the suite exposes
it, e.g. ``offload``) the raw measurement dict — so perf trajectories can be
tracked across commits as ``BENCH_*.json`` files.

``--check PATH`` compares this run against a recorded ``BENCH_*.json`` and
exits non-zero when a headline metric regressed by more than ``--tolerance``
(default 30%).  Row units drive the comparison direction: ``*/s`` rates must
not drop, ``us*`` latencies must not grow, count-like units (collectives,
puts, dispatches, bytes) must match exactly; cold-start rows
(compile-dominated) are skipped.  Two extra rules:

* every ``model_error`` row of the *current* run must sit below the paper's
  15 % bar (§6), recorded or not — the staging cost model is validated on
  each check, not just pinned against history;
* on failure, a per-metric ``measured / recorded / delta`` table of every
  compared row is printed so the drift is diagnosable from the CI log.

CI wires a deterministic ``--only`` subset (fig07, fig12, staging,
session, scheduler, faults) through this so benchmark bit-rot breaks
the build.  The
``session`` suite (``benchmarks/session_bench.py``) pins the session
API's estimate contract — every ``Session.estimate`` prediction within
the 15 % bar — and the AUTO planner's decision signature.
"""

import argparse
import json
import sys
import time

#: suite registry: name -> one-line description.  Static — ``--list`` /
#: ``--only`` validation must not import the (jax-heavy) benchmark
#: modules; main() asserts the registry matches the runtime suite dict.
SUITES = {
    "fig07": "offload overhead vs n, baseline vs multicast (paper fig. 7)",
    "fig08": "speedup restoration of the extensions (paper fig. 8)",
    "fig09": "per-phase offload breakdown at n=32 (paper fig. 9)",
    "fig10": "multicast wakeup scaling (paper fig. 10)",
    "fig11": "phase min/avg/max bands across clusters (paper fig. 11)",
    "fig12": "analytical-model error vs simulator (paper fig. 12)",
    "decision": "the model-driven offload decision (§1/§5.6)",
    "kernels": "paper kernels vs pure-JAX reference wallclock",
    "offload": "dispatch fast-path wallclock (resident vs re-staged)",
    "stream": "pipelined/fused/AUTO session dispatch throughput",
    "serve_stream": "serve decode modes + continuous batching tok/s",
    "staging": "hierarchical staging cost model vs discrete event",
    "staging_wall": "host_fanout vs tree staging wallclock sweep",
    "session": "session estimate contract + AUTO decision signature",
    "scheduler": "fabric scheduler: utilization, placement regret, "
                 "makespan model",
    "faults": "fault recovery: bit-exact results, overhead + recovery "
              "model error",
    "preempt": "overload ladder: churn replay, p99/utilization under "
               "preemption, bit-exact preempt/resume",
    "dag": "dependent job graphs: chain latency vs critical path, 0-byte "
           "intermediate d2h, diamond overlap",
    "perflint": "perf linter: autofix regret vs model-optimal, corpus "
                "gate, lint wallclock overhead",
}

#: suites the CI bench-smoke gate runs (`make bench-smoke` / ci.yml)
CI_SUITES = ("fig07", "fig12", "staging", "session", "scheduler", "faults",
             "preempt", "dag", "perflint")

#: row-name fragments excluded from --check (compile-dominated, unbounded noise)
CHECK_SKIP = ("/cold", "/error", "unix_time", "/verify/", "/lint/")


def _direction(unit: str) -> str:
    """-> "higher" | "lower" | "exact" for a row's unit string."""
    if unit.endswith("/s"):
        return "higher"
    if unit.startswith("us") or unit.startswith("cycles"):
        return "lower"
    if unit in ("overhead_cycles", "percent"):   # error/overhead: shrinking ok
        return "lower"
    if unit == "speedup":
        return "higher"
    return "exact"


#: the paper's analytical-model accuracy bar (§6): every model_error row of
#: the current run must sit strictly below this, recorded or not
MODEL_ERROR_BAR = 15.0


def _check_rows(report: dict, recorded: dict, tolerance: float) -> list:
    """-> [(suite, name, unit, recorded, measured, delta%, verdict)]."""
    out = []
    for suite, entry in report["suites"].items():
        ref = recorded.get("suites", {}).get(suite)
        if ref is None or "rows" not in entry or "rows" not in ref:
            continue
        ref_rows = {r["name"]: r for r in ref["rows"]}
        for row in entry["rows"]:
            name = row["name"]
            old = ref_rows.get(name)
            if old is None or any(s in name for s in CHECK_SKIP):
                continue
            new_v, old_v, unit = row["value"], old["value"], row["unit"]
            direction = _direction(unit)
            delta = ((new_v - old_v) / old_v * 100.0 if old_v else
                     (0.0 if new_v == old_v else float("inf")))
            if direction == "exact":
                verdict = "ok" if new_v == old_v else "REGRESSION"
            elif direction == "higher":
                verdict = ("ok" if new_v >= old_v * (1.0 - tolerance)
                           else "REGRESSION")
            else:
                verdict = ("ok" if new_v <= old_v * (1.0 + tolerance)
                           else "REGRESSION")
            out.append((suite, name, unit, old_v, new_v, delta, verdict))
    return out


def _model_error_bar(report: dict) -> list:
    """model_error rows of the current run violating the <15 % bar."""
    bad = []
    for suite, entry in report["suites"].items():
        for row in entry.get("rows", []):
            if ("model_error" in row["name"] and row["unit"] == "percent"
                    and row["value"] >= MODEL_ERROR_BAR):
                bad.append((suite, row["name"], row["value"]))
    return bad


def check_against(report: dict, recorded: dict, tolerance: float) -> int:
    """Compare common rows; returns the number of regressions (printed)."""
    rows = _check_rows(report, recorded, tolerance)
    regressions = [r for r in rows if r[-1] == "REGRESSION"]
    for _, name, unit, old_v, new_v, delta, _ in regressions:
        print(f"# REGRESSION {name} [{unit}]: {old_v:.3f} -> {new_v:.3f} "
              f"({delta:+.1f}%, tolerance {tolerance * 100:.0f}%)",
              file=sys.stderr)
    bar = _model_error_bar(report)
    for _, name, value in bar:
        print(f"# MODEL ERROR {name}: {value:.2f}% >= {MODEL_ERROR_BAR}% "
              "(the paper's §6 accuracy bar)", file=sys.stderr)
    failures = len(regressions) + len(bar)
    if failures:
        # full measured/recorded/delta table: make the drift diagnosable
        # from the CI log without a local rerun
        w = max([len(r[1]) for r in rows] or [4])
        print(f"# {'metric'.ljust(w)}  {'measured':>14}  {'recorded':>14}  "
              f"{'delta':>8}  verdict", file=sys.stderr)
        for _, name, unit, old_v, new_v, delta, verdict in rows:
            print(f"# {name.ljust(w)}  {new_v:>14.3f}  {old_v:>14.3f}  "
                  f"{delta:>+7.1f}%  {verdict}", file=sys.stderr)
    print(f"# check: {len(rows)} rows compared, {failures} failures "
          f"({len(regressions)} regressions, {len(bar)} model-error-bar)",
          file=sys.stderr)
    return failures


def list_suites() -> None:
    """``--list``: every suite, its description, and its CI coverage."""
    w = max(len(k) for k in SUITES)
    print(f"{'suite'.ljust(w)}  {'ci gate'.ljust(12)}  description")
    for name, desc in SUITES.items():
        gate = "bench-smoke" if name in CI_SUITES else "-"
        print(f"{name.ljust(w)}  {gate.ljust(12)}  {desc}")
    print(f"\n{len(SUITES)} suites; 'bench-smoke' = gated by "
          "`make bench-smoke` / the ci.yml regression check against "
          "BENCH_offload.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--list", action="store_true",
                    help="print every suite with its description and CI "
                         "gate, then exit")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig07,fig12 "
                         "(unknown names are an error)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as structured JSON to PATH")
    ap.add_argument("--check", default=None, metavar="PATH",
                    help="compare headline metrics against a recorded "
                         "BENCH_*.json; exit non-zero on regression")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed relative regression for --check "
                         "(default 0.30)")
    args = ap.parse_args()

    if args.list:
        list_suites()
        return
    keep = None
    if args.only:
        keep = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = sorted(set(keep) - set(SUITES))
        if unknown:
            ap.error(f"unknown suite(s) {', '.join(unknown)}; valid: "
                     f"{', '.join(SUITES)} (see --list)")

    from benchmarks.dag_bench import dag_suite
    from benchmarks.faults_bench import faults_suite
    from benchmarks.kernel_bench import kernel_table
    from benchmarks.offload_wallclock import (
        offload_wallclock, serve_throughput, staging_wall, stream_wallclock,
    )
    from benchmarks.paper_figs import ALL_FIGS
    from benchmarks.perflint_bench import perflint_suite
    from benchmarks.preempt_bench import preempt_suite
    from benchmarks.scheduler_bench import scheduler_suite
    from benchmarks.session_bench import session_suite
    from benchmarks.staging import staging_suite

    suites = dict(ALL_FIGS)
    suites["kernels"] = kernel_table
    suites["offload"] = offload_wallclock
    suites["stream"] = stream_wallclock
    suites["serve_stream"] = serve_throughput
    suites["staging"] = staging_suite
    suites["staging_wall"] = staging_wall
    suites["session"] = session_suite
    suites["scheduler"] = scheduler_suite
    suites["faults"] = faults_suite
    suites["preempt"] = preempt_suite
    suites["dag"] = dag_suite
    suites["perflint"] = perflint_suite
    missing = sorted(set(suites) ^ set(SUITES))
    assert not missing, f"suite registry out of sync: {missing}"
    if keep is not None:
        suites = {k: v for k, v in suites.items() if k in keep}

    report = {"schema": 1, "unix_time": time.time(), "suites": {}}
    print("name,us_per_call,derived")
    failures = 0
    for key, fn in suites.items():
        try:
            rows, derived = fn()
        except Exception as e:                              # noqa: BLE001
            print(f"{key}/ERROR,0,{e!r}")
            report["suites"][key] = {"error": repr(e)}
            failures += 1
            continue
        for name, val, unit in rows:
            print(f"{name},{val:.3f},{unit}")
        print(f"{key}/SUMMARY,0,{derived}")
        entry = {
            "rows": [{"name": n, "value": v, "unit": u} for n, v, u in rows],
            "derived": derived,
        }
        raw = getattr(fn, "last_raw", None)
        if raw:
            entry["raw"] = raw
        report["suites"][key] = entry

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)
    if args.check:
        with open(args.check) as f:
            recorded = json.load(f)
        failures += check_against(report, recorded, args.tolerance)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
