"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig07,fig12,...] \\
        [--json BENCH_offload.json]

Prints ``name,us_per_call,derived`` CSV.  Simulator-backed figures report
modeled cycles (1 cycle = 1 ns at the paper's 1 GHz testbench); `derived`
carries each figure's headline statistic next to the paper's published
value.

``--json PATH`` additionally writes the run as structured JSON — one entry
per suite with its rows, the derived headline, and (where the suite exposes
it, e.g. ``offload``) the raw measurement dict — so perf trajectories can be
tracked across commits as ``BENCH_*.json`` files.
"""

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig07,fig12")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as structured JSON to PATH")
    args = ap.parse_args()

    from benchmarks.kernel_bench import kernel_table
    from benchmarks.offload_wallclock import offload_wallclock
    from benchmarks.paper_figs import ALL_FIGS

    suites = dict(ALL_FIGS)
    suites["kernels"] = kernel_table
    suites["offload"] = offload_wallclock
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}

    report = {"schema": 1, "unix_time": time.time(), "suites": {}}
    print("name,us_per_call,derived")
    failures = 0
    for key, fn in suites.items():
        try:
            rows, derived = fn()
        except Exception as e:                              # noqa: BLE001
            print(f"{key}/ERROR,0,{e!r}")
            report["suites"][key] = {"error": repr(e)}
            failures += 1
            continue
        for name, val, unit in rows:
            print(f"{name},{val:.3f},{unit}")
        print(f"{key}/SUMMARY,0,{derived}")
        entry = {
            "rows": [{"name": n, "value": v, "unit": u} for n, v, u in rows],
            "derived": derived,
        }
        raw = getattr(fn, "last_raw", None)
        if raw:
            entry["raw"] = raw
        report["suites"][key] = entry

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
