"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig07,fig12,...]

Prints ``name,us_per_call,derived`` CSV.  Simulator-backed figures report
modeled cycles (1 cycle = 1 ns at the paper's 1 GHz testbench); `derived`
carries each figure's headline statistic next to the paper's published
value.
"""

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig07,fig12")
    args = ap.parse_args()

    from benchmarks.kernel_bench import kernel_table
    from benchmarks.offload_wallclock import offload_wallclock
    from benchmarks.paper_figs import ALL_FIGS

    suites = dict(ALL_FIGS)
    suites["kernels"] = kernel_table
    suites["offload"] = offload_wallclock
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}

    print("name,us_per_call,derived")
    failures = 0
    for key, fn in suites.items():
        try:
            rows, derived = fn()
        except Exception as e:                              # noqa: BLE001
            print(f"{key}/ERROR,0,{e!r}")
            failures += 1
            continue
        for name, val, unit in rows:
            print(f"{name},{val:.3f},{unit}")
        print(f"{key}/SUMMARY,0,{derived}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
