"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig07,fig12,...] \\
        [--json BENCH_offload.json] [--check BENCH_offload.json]

Prints ``name,us_per_call,derived`` CSV.  Simulator-backed figures report
modeled cycles (1 cycle = 1 ns at the paper's 1 GHz testbench); `derived`
carries each figure's headline statistic next to the paper's published
value.

``--json PATH`` additionally writes the run as structured JSON — one entry
per suite with its rows, the derived headline, and (where the suite exposes
it, e.g. ``offload``) the raw measurement dict — so perf trajectories can be
tracked across commits as ``BENCH_*.json`` files.

``--check PATH`` compares this run against a recorded ``BENCH_*.json`` and
exits non-zero when a headline metric regressed by more than ``--tolerance``
(default 30%).  Row units drive the comparison direction: ``*/s`` rates must
not drop, ``us*`` latencies must not grow, count-like units (collectives,
puts, dispatches) must match exactly; cold-start rows (compile-dominated)
are skipped.  CI wires a deterministic ``--only`` subset through this so
benchmark bit-rot breaks the build.
"""

import argparse
import json
import sys
import time

#: row-name fragments excluded from --check (compile-dominated, unbounded noise)
CHECK_SKIP = ("/cold", "/error", "unix_time")


def _direction(unit: str) -> str:
    """-> "higher" | "lower" | "exact" for a row's unit string."""
    if unit.endswith("/s"):
        return "higher"
    if unit.startswith("us") or unit.startswith("cycles"):
        return "lower"
    if unit in ("overhead_cycles", "percent"):   # error/overhead: shrinking ok
        return "lower"
    if unit == "speedup":
        return "higher"
    return "exact"


def check_against(report: dict, recorded: dict, tolerance: float) -> int:
    """Compare common rows; returns the number of regressions (printed)."""
    regressions = 0
    compared = 0
    for suite, entry in report["suites"].items():
        ref = recorded.get("suites", {}).get(suite)
        if ref is None or "rows" not in entry or "rows" not in ref:
            continue
        ref_rows = {r["name"]: r for r in ref["rows"]}
        for row in entry["rows"]:
            name = row["name"]
            old = ref_rows.get(name)
            if old is None or any(s in name for s in CHECK_SKIP):
                continue
            new_v, old_v, unit = row["value"], old["value"], row["unit"]
            direction = _direction(unit)
            compared += 1
            if direction == "exact":
                bad = new_v != old_v
                detail = f"{old_v} -> {new_v} (must match exactly)"
            elif direction == "higher":
                bad = new_v < old_v * (1.0 - tolerance)
                detail = f"{old_v:.3f} -> {new_v:.3f} (floor {old_v * (1 - tolerance):.3f})"
            else:
                bad = new_v > old_v * (1.0 + tolerance)
                detail = f"{old_v:.3f} -> {new_v:.3f} (ceiling {old_v * (1 + tolerance):.3f})"
            if bad:
                regressions += 1
                print(f"# REGRESSION {name} [{unit}]: {detail}",
                      file=sys.stderr)
    print(f"# check: {compared} rows compared, {regressions} regressions",
          file=sys.stderr)
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig07,fig12")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as structured JSON to PATH")
    ap.add_argument("--check", default=None, metavar="PATH",
                    help="compare headline metrics against a recorded "
                         "BENCH_*.json; exit non-zero on regression")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed relative regression for --check "
                         "(default 0.30)")
    args = ap.parse_args()

    from benchmarks.kernel_bench import kernel_table
    from benchmarks.offload_wallclock import (
        offload_wallclock, serve_throughput, stream_wallclock,
    )
    from benchmarks.paper_figs import ALL_FIGS

    suites = dict(ALL_FIGS)
    suites["kernels"] = kernel_table
    suites["offload"] = offload_wallclock
    suites["stream"] = stream_wallclock
    suites["serve_stream"] = serve_throughput
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}

    report = {"schema": 1, "unix_time": time.time(), "suites": {}}
    print("name,us_per_call,derived")
    failures = 0
    for key, fn in suites.items():
        try:
            rows, derived = fn()
        except Exception as e:                              # noqa: BLE001
            print(f"{key}/ERROR,0,{e!r}")
            report["suites"][key] = {"error": repr(e)}
            failures += 1
            continue
        for name, val, unit in rows:
            print(f"{name},{val:.3f},{unit}")
        print(f"{key}/SUMMARY,0,{derived}")
        entry = {
            "rows": [{"name": n, "value": v, "unit": u} for n, v, u in rows],
            "derived": derived,
        }
        raw = getattr(fn, "last_raw", None)
        if raw:
            entry["raw"] = raw
        report["suites"][key] = entry

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)
    if args.check:
        with open(args.check) as f:
            recorded = json.load(f)
        failures += check_against(report, recorded, args.tolerance)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
