"""Preemption & overload contract — deterministic, part of the CI subset.

Three claims of the PR-7 overload-robust fabric (`repro.core.fabric`
revocable leases + SLO admission + graceful degradation), pinned
numerically:

* **zero lost jobs under churn** — a seeded serve×offload arrival trace
  (decode bursts holding an elastic lease + offload tenant arrivals of
  mixed sizes, weights, priorities, and SLOs) replayed through a real
  ``preemption="priority"`` scheduler ends with every arrival accounted:
  granted (immediately, by backfill, or resumed after a preemption) or
  shed with a typed :class:`Overloaded` — never silently dropped.  The
  suite asserts the invariant itself; the ladder counters (preemptions,
  migrations, floor shrinks, degraded grants, sheds) are exact-compare
  rows.

* **p99 / utilization under preemption** — the discrete-event fabric
  model (`simulate_fabric` + :class:`PreemptionEvent`) replays the
  scheduler's own ladder decisions on a serve + batch + priority-burst
  scenario: the burst tenant's completion improves over non-preemptive
  FIFO sharing by >= the speedup bar while fabric utilization stays
  >= the utilization bar of FIFO's, and the serve tenant's p99
  inter-token latency stays <= the p99 bar times its quiet (no-churn)
  baseline.  The closed-form `fabric_makespan_model` predicts both the
  churn and the FIFO makespan within the paper's §6 bar (the
  ``model_error`` rows feed the harness's hard <15 % check).

* **bit-identical preemption** — on the 8-device XLA host platform, a
  session whose lease is preempted mid-stream (in-flight window drained
  under the model deadline, resident operands snapshotted, lease
  re-placed, operands restaged through the broadcast tree) returns
  results bit-equal to the unpreempted run — including with a composed
  :class:`FaultPlan` injecting faults across the preemption.

Needs the 8-device XLA host platform (the bench-smoke XLA_FLAGS) for the
bit-exactness scenario; everything else is deterministic model
arithmetic.
"""

from __future__ import annotations

import math
import random
from typing import List, Tuple

from repro.core import jobs, simulator
from repro.core.fabric import (
    ClusterLease,
    FabricScheduler,
    Overloaded,
    SchedulerPolicy,
    Tenant,
)
from repro.core.policy import TenantKind
from repro.core.simulator import (
    PreemptionEvent,
    TenantWorkload,
    fabric_makespan_model,
    simulate_fabric,
)

Row = Tuple[str, float, str]

#: acceptance bars (ISSUE-7): asserted by the suite itself
BURST_SPEEDUP_BAR = 1.2     # priority burst completes >= this much earlier
UTILIZATION_BAR = 0.85      # churn keeps >= this fraction of FIFO utilization
P99_BAR = 2.0               # serve p99 token latency <= bar x quiet baseline


# ---------------------------------------------------------------------------
# Claim 1: trace-driven churn replay — zero lost jobs.
# ---------------------------------------------------------------------------

CHURN_ARRIVALS = 40
CHURN_SEED = 7


def _churn_rows() -> Tuple[List[Row], dict]:
    rng = random.Random(CHURN_SEED)
    sched = FabricScheduler(
        num_clusters=32,
        policy=SchedulerPolicy(preemption="priority", max_queue_depth=4,
                               aging_grants=4))
    decode = jobs.make_matmul(16, 16, 16)
    serve = sched.request(Tenant("serve", kind=TenantKind.SERVE, weight=4.0,
                                 priority=2), n=16, job=decode, batch=64)
    sched.register_elastic(serve, floor=8)

    offload_job = jobs.make_covariance(32, 64)
    # priority arrivals ask for the full 16-wide window with a job whose
    # makespan really needs it (8-wide is ~1.2x) — degradation cannot
    # satisfy them, so the preempt rung fires on a loaded fabric
    priority_job = jobs.make_covariance(128, 256)
    granted = shed = 0
    live: List[List] = []        # [lease, steps-to-hold]
    queued: List = []            # PendingLease objects we are polling
    for t in range(CHURN_ARRIVALS):
        # departures: expire holds; a preempted lease (not current) stays
        # until its re-placement lands, then releases
        for entry in list(live):
            entry[1] -= 1
            if entry[1] > 0:
                continue
            cur = sched.current_lease(entry[0])
            if cur is None:
                continue         # revoked, awaiting re-place — retry later
            sched.release(cur)
            live.remove(entry)
        for pend in list(queued):
            if pend.ready:
                granted += 1
                queued.remove(pend)
                live.append([pend.lease, rng.randint(2, 6)])
        prio = rng.choice([0, 0, 0, 1])
        ten = Tenant(f"o{t}", weight=float(rng.choice([1, 1, 2])),
                     priority=prio,
                     slo=(150_000.0 if rng.random() < 0.25 else None))
        n = 16 if prio else rng.choice([2, 4, 8])
        try:
            res = sched.request(ten, n=n,
                                job=priority_job if prio else offload_job,
                                batch=4, queue=True)
        except Overloaded as e:
            assert e.retry_after_cycles >= 0.0
            shed += 1
            continue
        if isinstance(res, ClusterLease):
            granted += 1
            live.append([res, rng.randint(2, 6)])
        else:
            queued.append(res)

    # drain: release what remains; freed capacity grants the queue and
    # re-places preempted leases until everything is accounted
    for _ in range(10 * CHURN_ARRIVALS):
        if not live and not queued:
            break
        for entry in list(live):
            cur = sched.current_lease(entry[0])
            if cur is not None:
                sched.release(cur)
                live.remove(entry)
        for pend in list(queued):
            if pend.ready:
                granted += 1
                queued.remove(pend)
                live.append([pend.lease, 0])
    assert not live and not queued, (
        f"churn drain left work behind: {len(live)} live, "
        f"{len(queued)} queued")
    h = sched.health()
    assert granted + shed == CHURN_ARRIVALS, (
        f"lost jobs: {granted} granted + {shed} shed != "
        f"{CHURN_ARRIVALS} arrivals")
    assert shed == h.overloaded, "sheds must all be typed Overloaded"
    assert not sched.pending, "drained fabric still has queued requests"
    assert sched.leases == (sched.current_lease(serve),), (
        "only the serve lease should survive the drain")
    rows: List[Row] = [
        ("preempt/churn/arrivals", float(CHURN_ARRIVALS), "count"),
        ("preempt/churn/granted", float(granted), "count"),
        ("preempt/churn/shed_overloaded", float(shed), "count"),
        ("preempt/churn/preemptions", float(h.preemptions), "count"),
        ("preempt/churn/migrations", float(h.migrations), "count"),
        ("preempt/churn/floor_shrinks", float(h.floor_shrinks), "count"),
        ("preempt/churn/degraded_grants", float(h.degraded_grants), "count"),
    ]
    return rows, {"granted": granted, "shed": shed,
                  "preemptions": h.preemptions}


# ---------------------------------------------------------------------------
# Claim 2: p99 / utilization under a scheduler-driven preemption timeline.
# ---------------------------------------------------------------------------

SERVE_STEPS = 64       # decode steps (token latencies)
BATCH_JOBS = 16
BURST_JOBS = 12


def _p99(latencies: List[float]) -> float:
    ordered = sorted(latencies)
    return ordered[min(len(ordered) - 1,
                       max(0, math.ceil(0.99 * len(ordered)) - 1))]


def _token_latencies(completions: List[float], arrival: float) -> List[float]:
    out = []
    prev = arrival
    for c in completions:
        out.append(c - prev)
        prev = c
    return out


def _timing_rows() -> Tuple[List[Row], dict]:
    decode = jobs.make_matmul(16, 16, 16)
    batch_job = jobs.make_atax(256, 256)         # heavy enough that FIFO
                                                 # sharing really hurts
    burst_job = jobs.make_covariance(128, 256)   # needs the full 16-wide
                                                 # window (8-wide is ~1.2x)

    # drive the real ladder: serve holds an elastic 16 with floor 8, a
    # low-priority batch tenant owns the other 16, and a priority burst
    # arrives asking for 16 — compaction finds nothing, the serve lease
    # shrinks to its floor, degrading cannot reach model-equal makespan,
    # so the batch lease is revoked and re-queued
    sched = FabricScheduler(
        num_clusters=32, policy=SchedulerPolicy(preemption="priority"))
    serve = sched.request(Tenant("serve", kind=TenantKind.SERVE, weight=4.0,
                                 priority=2), n=16, job=decode,
                          batch=SERVE_STEPS)
    sched.register_elastic(serve, floor=8)
    victim = sched.request(Tenant("batch", priority=0), n=16, job=batch_job,
                           batch=BATCH_JOBS)
    burst = sched.request(Tenant("burst", priority=1, weight=2.0), n=16,
                          job=burst_job, batch=BURST_JOBS)
    h = sched.health()
    assert h.preemptions == 1, "the ladder should revoke the batch lease"
    assert h.floor_shrinks == 0 and sched.current_lease(serve).n == 8, (
        "the serve lease should shrink to its floor before any revocation")
    pend = next(p for p in sched.pending
                if p.resume_id == victim.lease_id)
    drain = sched.drain_deadline(burst)      # same formula the victim got
    # the victim's re-placement waits out the usurper's model ETA (what
    # predict_retry_after reports) and then pays the operand restage
    burst_eta = sched.predict_makespan(burst_job, burst.clusters, BURST_JOBS)
    sched.release(burst)
    assert pend.ready, "freed capacity must re-place the preempted lease"
    resumed = pend.lease
    restage = burst_eta + sched.placement_cost(
        resumed.clusters, sched._stage_bytes(batch_job))

    serve_w = tuple(serve.clusters)          # the original 16-wide window
    shrunk_w = tuple(sched.current_lease(serve).clusters)
    batch_w = tuple(victim.clusters)
    burst_w = tuple(burst.clusters)

    # quiet baseline: serve alone (p99 reference), batch alone (to place
    # the burst arrival at its 6th completion, where the revocation lands)
    quiet_serve = simulate_fabric(
        [TenantWorkload("serve", decode.spec, serve_w, jobs=SERVE_STEPS,
                        window=2)])
    quiet_batch = simulate_fabric(
        [TenantWorkload("batch", batch_job.spec, batch_w, jobs=BATCH_JOBS)])
    preempt_after = 6
    arrival = quiet_batch.job_completions["batch"][preempt_after - 1]

    workloads = [
        TenantWorkload("serve", decode.spec, serve_w, jobs=SERVE_STEPS,
                       window=2),
        TenantWorkload("batch", batch_job.spec, batch_w, jobs=BATCH_JOBS),
        TenantWorkload("burst", burst_job.spec, burst_w, jobs=BURST_JOBS,
                       arrival=arrival),
    ]
    events = [
        PreemptionEvent("serve", after_jobs=preempt_after,
                        new_clusters=shrunk_w),
        PreemptionEvent("batch", after_jobs=preempt_after,
                        new_clusters=tuple(resumed.clusters),
                        restage_cycles=restage),
    ]
    churn = simulate_fabric(workloads, preemptions=events)
    churn_pred = fabric_makespan_model(workloads, preemptions=events)
    churn_err = simulator.model_error(churn_pred, churn.makespan)
    fifo = simulate_fabric(workloads)        # no revocation: FIFO sharing
    fifo_pred = fabric_makespan_model(workloads)
    fifo_err = simulator.model_error(fifo_pred, fifo.makespan)

    burst_churn = churn.completion["burst"] - arrival
    burst_fifo = fifo.completion["burst"] - arrival
    speedup = burst_fifo / burst_churn
    util = churn.utilization(32) / fifo.utilization(32)
    p99_quiet = _p99(_token_latencies(quiet_serve.job_completions["serve"],
                                      0.0))
    p99_churn = _p99(_token_latencies(churn.job_completions["serve"], 0.0))
    p99_ratio = p99_churn / p99_quiet

    assert speedup >= BURST_SPEEDUP_BAR, (
        f"burst completion speedup {speedup:.2f}x under preemption below "
        f"the {BURST_SPEEDUP_BAR}x bar (churn {burst_churn:.0f} cyc vs "
        f"FIFO {burst_fifo:.0f} cyc)")
    assert util >= UTILIZATION_BAR, (
        f"churn utilization {util:.2f}x of FIFO below the "
        f"{UTILIZATION_BAR}x bar")
    assert p99_ratio <= P99_BAR, (
        f"serve p99 token latency {p99_ratio:.2f}x of quiet baseline "
        f"above the {P99_BAR}x bar")
    rows: List[Row] = [
        ("preempt/churn/makespan", churn.makespan, "cycles"),
        ("preempt/churn/predicted", churn_pred, "cycles"),
        ("preempt/churn/model_error", churn_err * 100, "percent"),
        ("preempt/fifo/makespan", fifo.makespan, "cycles"),
        ("preempt/fifo/predicted", fifo_pred, "cycles"),
        ("preempt/fifo/model_error", fifo_err * 100, "percent"),
        ("preempt/burst/speedup_vs_fifo", speedup, "speedup"),
        ("preempt/utilization_vs_fifo", util, "ratio"),
        ("preempt/serve/p99_token_quiet", p99_quiet, "cycles"),
        ("preempt/serve/p99_token_churn", p99_churn, "cycles"),
        ("preempt/drain_deadline", drain, "cycles"),
    ]
    return rows, {"speedup": speedup, "util": util, "p99_ratio": p99_ratio,
                  "errs": [churn_err * 100, fifo_err * 100]}


# ---------------------------------------------------------------------------
# Claim 3: bit-identical preemption (8-device XLA host platform).
# ---------------------------------------------------------------------------


def _bitexact_rows() -> List[Row]:
    import jax
    import numpy as np

    from repro.api import (
        FaultInjector, FaultKind, FaultPlan, FaultSpec, OffloadPolicy,
        Residency, RetryPolicy, Session,
    )

    job = jobs.make_axpy(512)
    ops, _ = job.make_instance(0)
    fresh_ops = [job.make_instance(i)[0] for i in (1, 2, 3)]

    # unpreempted reference: resident submits + fresh submits on one lease
    sched = FabricScheduler(jax.devices())
    lease = sched.request(Tenant("ref"), clusters=[0, 1, 2, 3])
    sess = Session(lease=lease)
    sess.stage(job, dict(ops), n=4)
    ref_res = [np.asarray(sess.submit(job, Residency.RESIDENT, n=4).wait())
               for _ in range(2)]
    ref_fresh = [np.asarray(sess.submit(job, dict(o), n=4).wait())
                 for o in fresh_ops]
    sess.close()

    # preempted run: mid-stream revoke, drain, snapshot, re-place, restage
    sched = FabricScheduler(jax.devices())
    victim = sched.request(Tenant("victim"), clusters=[0, 1, 2, 3])
    blocker = sched.request(Tenant("blocker"), clusters=[4, 5, 6, 7])
    # a queued heavier tenant takes the freed window first, so the
    # preempted lease really waits and resumes on a *different* window
    taker = sched.request(Tenant("taker", weight=8.0), n=4, queue=True)
    sess = Session(lease=victim)
    sess.stage(job, dict(ops), n=4)
    out = [np.asarray(sess.submit(job, Residency.RESIDENT, n=4).wait())]
    pend = sched.preempt(victim)
    assert taker.ready, "the queued tenant should take the freed window"
    assert not pend.ready, "no free window: the re-placement must queue"
    try:
        sess.submit(job, Residency.RESIDENT, n=4)
        raise AssertionError("suspended session accepted a submit")
    except RuntimeError:
        pass
    sched.release(blocker)                   # frees capacity -> re-place
    assert pend.ready and pend.lease.lease_id == victim.lease_id
    assert tuple(pend.lease.clusters) == (4, 5, 6, 7), (
        "the resumed lease should land on the freed window")
    restaged = sched.health().restaged_operands
    assert restaged >= len(ops), "resident operands were not restaged"
    out.append(np.asarray(sess.submit(job, Residency.RESIDENT, n=4).wait()))
    out_fresh = [np.asarray(sess.submit(job, dict(o), n=4).wait())
                 for o in fresh_ops]
    sess.close()
    for got, exp in zip(out + out_fresh, ref_res + ref_fresh):
        assert np.array_equal(got, exp), (
            "preempted run is not bit-identical to the unpreempted run")

    # chaos composition: a FaultPlan composed from two single-fault plans
    # rides across a preemption — recovery and resume stay bit-identical
    plan = FaultPlan([FaultSpec(FaultKind.LOST_ARRIVAL, at_dispatch=0,
                                count=1)]).compose(
        FaultPlan([FaultSpec(FaultKind.STRAGGLE, at_dispatch=1,
                             factor=10.0)]))
    pol = OffloadPolicy(retry=RetryPolicy())
    sched = FabricScheduler(jax.devices())
    victim = sched.request(Tenant("victim"), clusters=[0, 1, 2, 3])
    blocker = sched.request(Tenant("blocker"), clusters=[4, 5, 6, 7])
    sess = Session(lease=victim, policy=pol, faults=FaultInjector(plan))
    got = [np.asarray(sess.submit(job, dict(fresh_ops[0]), n=4).wait())]
    pend = sched.preempt(victim)
    sched.release(blocker)
    assert pend.ready
    got.append(np.asarray(sess.submit(job, dict(fresh_ops[1]), n=4).wait()))
    sess.close()
    assert np.array_equal(got[0], ref_fresh[0])
    assert np.array_equal(got[1], ref_fresh[1])

    return [
        ("preempt/bitexact/resident", 1.0, "count"),
        ("preempt/bitexact/faulted", 1.0, "count"),
        ("preempt/bitexact/restaged_operands", float(restaged), "count"),
    ]


def preempt_suite() -> Tuple[List[Row], str]:
    churn_rows, churn = _churn_rows()
    timing_rows, timing = _timing_rows()
    rows = churn_rows + timing_rows + _bitexact_rows()
    derived = (
        f"churn: {churn['granted']}+{churn['shed']} of {CHURN_ARRIVALS} "
        f"arrivals granted+shed (zero lost, {churn['preemptions']} "
        f"preemptions); burst speedup {timing['speedup']:.2f}x over FIFO "
        f"(bar {BURST_SPEEDUP_BAR}x) at {timing['util']:.2f}x FIFO "
        f"utilization (bar {UTILIZATION_BAR}x); serve p99 "
        f"{timing['p99_ratio']:.2f}x quiet (bar {P99_BAR}x); makespan "
        f"model error max {max(timing['errs']):.2f}% (paper bar <15%); "
        "preempted runs bit-identical (resident + composed faults)")
    return rows, derived
