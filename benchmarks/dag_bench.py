"""Dependent job graphs (`dag`) — deterministic, part of the CI subset.

The ISSUE-8 acceptance contract for scoreboarded out-of-order dispatch
with device-to-device result forwarding, pinned numerically:

* **model rows** — the dependency-aware event model
  (:func:`simulate_graph`) vs the closed-form critical-path bound
  (:func:`graph_critical_path`): a K=8 self-scaling chain (``y ← a·y +
  y``, both operands read the previous node) across sizes sits within
  the paper's §6 < 15 % bar on every recorded point, a diamond with
  disjoint-selection arms likewise; chain graph latency lands at
  ``≤ RATIO_BAR ×`` the chained submit+wait baseline
  (:func:`isolated_graph_cycles` — one d2h fetch per unique producer
  plus one h2d restage per edge), and overlapping the diamond's arms
  beats serializing them by ``≥ OVERLAP_BAR``.

* **real-mesh rows** (8-device XLA host platform, the bench-smoke
  ``XLA_FLAGS``) — a K=8 chain through ``Session.submit_graph`` moves
  **exactly 0** intermediate d2h bytes (``PlanStats.d2h_bytes`` equals
  the final fetched result alone), forwards once per edge, and is
  bit-identical to sequential submit/wait execution; the diamond keeps
  both arms in flight concurrently.

Every bar is asserted by the suite itself — a violation fails the bench
run, and the ``model_error`` rows additionally feed the harness's hard
< 15 % check under ``--check``.
"""

from __future__ import annotations

from typing import List, Tuple

from repro.core import jobs
from repro.core.simulator import (
    GraphJob,
    graph_critical_path,
    isolated_graph_cycles,
    model_error,
    simulate_graph,
)

Row = Tuple[str, float, str]

#: acceptance bars (ISSUE-8): asserted by the suite itself
RATIO_BAR = 0.6       # chain graph latency <= bar x isolated baseline
OVERLAP_BAR = 1.15    # serialized diamond arms / overlapped >= bar
MODEL_BAR = 15.0      # percent, the paper's §6 accuracy bar
#: ISSUE-9 bar: static verification of the chain graph costs < 5 % of
#: one warm dispatch of the same graph
VERIFY_BAR = 5.0

CHAIN_K = 8
CHAIN_SIZES = (256, 2048, 16384)
WINDOW = 4


def _chain(N: int, K: int = CHAIN_K) -> List[GraphJob]:
    """Self-scaling chain ``y ← a·y + y``: each link reads the previous
    node's result through *both* operands (two dataflow edges)."""
    spec = jobs.axpy_spec(N)
    sel = tuple(range(8))
    return [GraphJob(spec=spec, clusters=sel,
                     deps=(i - 1, i - 1) if i else (), out_bytes=N * 8)
            for i in range(K)]


def _model_rows() -> Tuple[List[Row], dict]:
    rows: List[Row] = []
    errs: List[float] = []
    for N in CHAIN_SIZES:
        nodes = _chain(N)
        ev = simulate_graph(nodes, window=WINDOW)
        cf = graph_critical_path(nodes)
        err = 100.0 * model_error(cf, ev.makespan)
        errs.append(err)
        assert err < MODEL_BAR, (N, cf, ev.makespan)
        rows.append((f"dag/chain/N{N}/model_error", err, "percent"))

    nodes = _chain(2048)
    ev = simulate_graph(nodes, window=WINDOW)
    iso = isolated_graph_cycles(nodes)
    ratio = ev.makespan / iso
    assert ratio <= RATIO_BAR, (ev.makespan, iso)
    rows += [
        ("dag/chain/N2048/graph", ev.makespan, "cycles"),
        ("dag/chain/N2048/isolated", iso, "cycles"),
        ("dag/chain/N2048/iso_speedup", iso / ev.makespan, "speedup"),
    ]

    spec = jobs.axpy_spec(8192)
    nb = 8192 * 8
    c8, left, right = tuple(range(8)), tuple(range(4)), tuple(range(4, 8))
    diamond = [
        GraphJob(spec=spec, clusters=c8, out_bytes=nb),
        GraphJob(spec=spec, clusters=left, deps=(0,), out_bytes=nb),
        GraphJob(spec=spec, clusters=right, deps=(0,), out_bytes=nb),
        GraphJob(spec=spec, clusters=c8, deps=(1, 2), out_bytes=nb),
    ]
    dev = simulate_graph(diamond, window=WINDOW)
    dcf = graph_critical_path(diamond)
    derr = 100.0 * model_error(dcf, dev.makespan)
    errs.append(derr)
    assert derr < MODEL_BAR, (dcf, dev.makespan)
    serial = [diamond[0], diamond[1],
              GraphJob(spec=spec, clusters=right, deps=(0, 1), out_bytes=nb),
              diamond[3]]
    sv = simulate_graph(serial, window=WINDOW)
    overlap = sv.makespan / dev.makespan
    assert overlap >= OVERLAP_BAR, (sv.makespan, dev.makespan)
    rows += [
        ("dag/diamond/model_error", derr, "percent"),
        ("dag/diamond/overlap_speedup", overlap, "speedup"),
    ]
    return rows, {"errs": errs, "ratio": ratio, "overlap": overlap}


def _chain_nodes(job, ops, K: int = CHAIN_K):
    from repro.core.scoreboard import GraphNode, Ref

    nodes = [GraphNode(job, ops, name="n0")]
    for k in range(1, K):
        nodes.append(GraphNode(job, {"x": ops["x"], "y": Ref(f"n{k-1}")},
                               name=f"n{k}"))
    return nodes


def _diamond_nodes(job, ops):
    from repro.core.scoreboard import GraphNode, Ref

    return [
        GraphNode(job, ops, name="src"),
        GraphNode(job, {"x": ops["x"], "y": Ref("src")}, name="l",
                  clusters=[0, 1, 2, 3]),
        GraphNode(job, {"x": ops["x"], "y": Ref("src")}, name="r",
                  clusters=[4, 5, 6, 7]),
        GraphNode(job, {"x": Ref("l"), "y": Ref("r")}, name="join"),
    ]


def _serial_nodes(job, ops):
    from repro.core.scoreboard import GraphNode, Ref

    # serial wide -> narrow -> wide: both edges pay a d2d forward on
    # the critical path.  Kept as checked-in OFLP104 debt on purpose —
    # LINT_baseline.json carries its two findings, so `make
    # lint-graphs` stays green here but fails on *new* regressions.
    return [
        GraphNode(job, ops, name="wide"),
        GraphNode(job, {"x": ops["x"], "y": Ref("wide")}, name="narrow",
                  clusters=[0, 1, 2, 3]),
        GraphNode(job, {"x": ops["x"], "y": Ref("narrow")}, name="tail"),
    ]


def bench_graphs() -> dict:
    """name -> GraphNode list (the real-mesh graphs `_real_rows` runs),
    collected by the ``make verify-graphs`` zero-diagnostics gate.
    Operand dtype is irrelevant to verification, so plain numpy."""
    import numpy as np

    job = jobs.make_axpy(2048)
    ops, _ = job.make_instance(0)
    ops = {k: np.asarray(v) for k, v in ops.items()}
    return {"dag/chain": _chain_nodes(job, ops),
            "dag/diamond": _diamond_nodes(job, ops),
            "dag/serial": _serial_nodes(job, ops)}


def _real_rows() -> Tuple[List[Row], dict]:
    """8-device mesh: the graph path's byte counters and bit-identity."""
    import jax.numpy as jnp
    import numpy as np
    from repro.core.session import Session

    job = jobs.make_axpy(2048)
    ops, _ = job.make_instance(0)
    # plan in the substrate's default float width (x64 off in CI bench):
    # a forwarded result must match the planned operand dtype exactly
    dt = jnp.zeros(()).dtype
    ops = {k: np.asarray(v, dtype=dt) for k, v in ops.items()}

    sess = Session()
    nodes = _chain_nodes(job, ops)
    gh = sess.submit_graph(nodes)
    out = gh.wait()
    final = out[f"n{CHAIN_K - 1}"]
    # THE acceptance row: intermediate results moved 0 host-link bytes
    intermediate_d2h = float(sess.stats.d2h_bytes - final.nbytes)
    assert intermediate_d2h == 0.0, sess.stats.d2h_bytes
    assert sess.stats.forwards == CHAIN_K - 1

    seq = Session()
    y = dict(ops)
    for _ in range(CHAIN_K):
        r = seq.submit(job, y).wait()
        y = {"x": ops["x"], "y": r}
    bit_identical = float(np.array_equal(np.asarray(final), np.asarray(r)))
    assert bit_identical == 1.0

    gd = sess.submit_graph(_diamond_nodes(job, ops))
    gd.wait()
    assert gd.max_inflight >= 2
    sess.drain()
    seq.drain()

    # ISSUE-9: static verification overhead vs a warm dispatch of the
    # same K=8 chain.  Both sides are wallclock; the dispatch side
    # re-runs submit_graph (verifier on, cached plans) so the ratio is
    # conservative.
    import time

    from repro.analysis import verify_graph

    reps = 20
    t0 = time.perf_counter()
    for _ in range(reps):
        diags = verify_graph(nodes, n_units=sess.n_units,
                             default_width=8, session=sess)
    t_verify = (time.perf_counter() - t0) / reps
    assert not diags, diags
    t0 = time.perf_counter()
    sess.submit_graph(nodes).wait()
    t_dispatch = time.perf_counter() - t0
    verify_pct = 100.0 * t_verify / t_dispatch
    assert verify_pct < VERIFY_BAR, (t_verify, t_dispatch)

    rows = [
        ("dag/real/chain_intermediate_d2h", intermediate_d2h, "bytes"),
        ("dag/real/chain_forwards", float(CHAIN_K - 1), "count"),
        ("dag/real/chain_bit_identical", bit_identical, "count"),
        ("dag/real/diamond_max_inflight", float(gd.max_inflight), "count"),
        ("dag/real/seq_d2h_over_graph",
         float(seq.stats.d2h_bytes) / float(final.nbytes), "speedup"),
        ("dag/verify/chain_us", 1e6 * t_verify, "us"),
        ("dag/verify/overhead_pct", verify_pct, "percent"),
    ]
    return rows, {"max_inflight": gd.max_inflight,
                  "seq_d2h": seq.stats.d2h_bytes,
                  "verify_pct": verify_pct}


def dag_suite() -> Tuple[List[Row], str]:
    model_rows, model = _model_rows()
    real_rows, real = _real_rows()
    rows = model_rows + real_rows
    derived = (
        f"K={CHAIN_K} chain: graph latency {model['ratio']:.3f}x isolated "
        f"(bar <= {RATIO_BAR}x), intermediate d2h exactly 0 bytes, "
        "bit-identical to sequential; diamond arms overlap "
        f"{model['overlap']:.2f}x (bar >= {OVERLAP_BAR}x); model error "
        f"max {max(model['errs']):.2f}% (paper bar < {MODEL_BAR:.0f}%); "
        f"static verify overhead {real['verify_pct']:.2f}% of a warm "
        f"dispatch (bar < {VERIFY_BAR:.0f}%)")
    return rows, derived
