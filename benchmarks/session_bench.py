"""Session-API model contract — deterministic, part of the CI subset.

Two claims of the session API (`repro.api`), pinned numerically:

* **the estimate contract** — ``Session.estimate`` / ``repro.core.
  session.estimate`` predicts the offloaded runtime of every paper job
  within the paper's §6 accuracy bar (< 15 % vs. the discrete-event
  simulator) at every cluster count.  Each point is recorded as a
  ``predicted`` row plus a ``model_error`` row; ``benchmarks/run.py
  --check`` hard-fails any ``model_error`` at or above 15 %, recorded or
  not.

* **AUTO never loses** — the planner's model-driven mode selection,
  evaluated point-by-point against the simulator: the staging mode AUTO
  picks is never slower (in discrete-event cycles) than either
  hand-picked data path on the full staging grid, and the fused/windowed
  per-job prediction never exceeds the unfused one.  The decision
  signature at the bench shapes (fuse factor, window, tree staging) is
  recorded as exact-compare rows so a planner regression diffs loudly.

Pure model arithmetic — no devices, no wallclock noise; safe to gate CI.
"""

from __future__ import annotations

from typing import List, Tuple

from repro.core import jobs, simulator
from repro.core.policy import AUTO, Staging
from repro.core.session import Planner, estimate

Row = Tuple[str, float, str]

NS = (1, 2, 4, 8, 16, 32)

#: one representative size per paper kernel (the fig.-12 midpoints)
CASES = (
    ("axpy1024", lambda: jobs.make_axpy(1024)),
    ("atax64", lambda: jobs.make_atax(64, 64)),
    ("matmul16", lambda: jobs.make_matmul(16, 16, 16)),
    ("covariance32", lambda: jobs.make_covariance(32, 64)),
    ("montecarlo16k", lambda: jobs.make_montecarlo(16384)),
    ("bfs256", lambda: jobs.make_bfs(256)),
)

#: the staging-suite grid (benchmarks/staging.py) the AUTO pick is
#: validated against
STAGING_SIZES_KIB = (4, 64, 1024)


def session_suite() -> Tuple[List[Row], str]:
    rows: List[Row] = []
    planner = Planner()
    errs: List[float] = []

    # -- estimate contract: predicted vs simulated, every job x n ---------
    for name, mk in CASES:
        job = mk()
        for n in NS:
            est = estimate(job, n=n, policy=AUTO, planner=planner)
            sim = simulator.simulate(job.spec, n, "multicast").total
            err = simulator.model_error(est.job_cycles, sim)
            errs.append(err)
            rows.append((f"session/{name}/n={n}/predicted",
                         est.job_cycles, "cycles"))
            rows.append((f"session/{name}/n={n}/model_error",
                         err * 100, "percent"))

    # -- AUTO decision signature at the bench shapes ----------------------
    # cycle-domain decisions (a model-faithful serial-link substrate:
    # tree_min_bytes=0); the substrate guard is pinned separately below
    model_planner = Planner(tree_min_bytes=0)
    tree_picks = 0
    for name, mk in CASES:
        job = mk()
        est = estimate(job, n=8, batch=8, policy=AUTO, planner=model_planner)
        d = est.decision
        rows.append((f"session/auto/{name}/n=8/fuse", d.fuse, "jobs"))
        rows.append((f"session/auto/{name}/n=8/window", d.window, "count"))
        is_tree = 1.0 if d.staging is Staging.TREE else 0.0
        tree_picks += int(is_tree)
        rows.append((f"session/auto/{name}/n=8/tree_staging", is_tree,
                     "count"))
        # fused/windowed amortization never predicts worse than unfused
        unfused = planner.per_job_cycles(job.spec, 8, fuse=1, window=1)
        rows.append((f"session/auto/{name}/n=8/amortization",
                     unfused / est.per_job_cycles, "speedup"))

    # -- the substrate tree guard (Planner.TREE_MIN_BYTES) ----------------
    # the default planner stays on the native DIRECT path for sub-MiB
    # replicated footprints (this substrate's cache-dominated host link,
    # see staging_wall) and rides the tree once bandwidth-bound
    small = estimate(jobs.make_covariance(32, 64), n=8, policy=AUTO,
                     planner=planner)
    big = estimate(jobs.make_covariance(1024, 2048), n=8, policy=AUTO,
                   planner=planner)
    rows.append(("session/auto/substrate_guard/64KiB_tree",
                 1.0 if small.decision.staging is Staging.TREE else 0.0,
                 "count"))
    rows.append(("session/auto/substrate_guard/16MiB_tree",
                 1.0 if big.decision.staging is Staging.TREE else 0.0,
                 "count"))

    # -- AUTO staging pick vs both hand-picked data paths, full grid ------
    # regret := sim(chosen) / min(sim over modes); 1.0 everywhere means
    # the model-driven pick never loses a point of the recorded grid
    worst_regret = 1.0
    for kib in STAGING_SIZES_KIB:
        nbytes = kib * 1024
        for n in NS:
            pick = planner.pick_staging(nbytes, n)
            by_mode = {m: simulator.simulate_staging(nbytes, n, m)
                       for m in simulator.STAGING_MODES}
            chosen = by_mode["tree" if pick in (Staging.TREE,
                                                Staging.TREE_RESHARD)
                             else "host_fanout"]
            worst_regret = max(worst_regret,
                               chosen / min(by_mode.values()))
    rows.append(("session/auto/staging/max_regret", worst_regret, "ratio"))

    derived = (
        f"estimate max model error {max(errs) * 100:.1f}% over "
        f"{len(errs)} job/n points (paper bar <15%); AUTO picks tree "
        f"staging for {tree_picks}/{len(CASES)} kernels at n=8 (the "
        f"broadcast-class ones), staging regret {worst_regret:.3f}x over "
        f"the {len(STAGING_SIZES_KIB) * len(NS)}-point grid (1.0 = never "
        "slower than the best hand-picked mode)")
    return rows, derived
