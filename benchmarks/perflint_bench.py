"""Perf-linter acceptance bench — seeded inefficiencies, measured regret.

One deliberately suboptimal configuration per ``OFLP1##`` code; each is
linted, every machine-applicable autofix is applied, and the unfixed /
autofixed / model-optimal configurations are *measured* in the
deterministic cycle domain — the discrete-event simulator where one
exists (``simulate_staging`` for OFLP101, ``simulate_graph`` for
OFLP104), the shared amortization model otherwise.  Each case records

    perflint/<code>/regret_unfixed   measured(unfixed)  / measured(optimal)
    perflint/<code>/regret_fixed     measured(autofixed) / measured(optimal)

and self-asserts ``regret_fixed <= REGRET_BAR`` (1.05): the linter's
advice must recover the seeded waste, not merely shuffle it.  Two more
deterministic rows pin the CI gate itself (``perflint/corpus/*``: the
checked-in graphs carry zero non-baselined findings), and a subprocess
measures the wallclock cost of ``Session.submit(lint=True)`` on a warm
dispatch — self-asserting overhead < 5 % (``perflint/lint/*`` rows are
excluded from ``--check`` like every other pure-wallclock row).
"""

from __future__ import annotations

import json
import math
import os
import subprocess
import sys
import textwrap
from pathlib import Path
from typing import List, Tuple

Row = Tuple[str, float, str]

#: autofixed cycles may exceed model-optimal cycles by at most this factor
REGRET_BAR = 1.05

#: lint=True on a warm dispatch may cost at most this much extra wallclock
OVERHEAD_BAR_PCT = 5.0

_ROOT = Path(__file__).resolve().parent.parent


def _regret_rows() -> Tuple[List[Row], dict]:
    import numpy as np

    from repro.analysis import perflint
    from repro.core import jobs, simulator
    from repro.core import model as amodel
    from repro.core.params import DEFAULT_PARAMS
    from repro.core.policy import AUTO, Staging
    from repro.core.scoreboard import GraphNode, Ref
    from repro.core.session import Planner

    planner = Planner()
    p = DEFAULT_PARAMS
    rows: List[Row] = []
    raw: dict = {}

    def record(code: str, unfixed: float, fixed: float,
               optimal: float) -> None:
        ru, rf = unfixed / optimal, fixed / optimal
        assert fixed <= unfixed, (code, unfixed, fixed)
        assert rf <= REGRET_BAR, (code, fixed, optimal, rf)
        rows.append((f"perflint/{code}/regret_unfixed", ru, "ratio"))
        rows.append((f"perflint/{code}/regret_fixed", rf, "ratio"))
        raw[code] = {"unfixed": unfixed, "fixed": fixed,
                     "optimal": optimal}

    def codes(findings) -> set:
        return {f.code for f in findings}

    ids8 = list(range(8))

    # OFLP101 — staging pinned to the O(n) host fan-out on a large
    # replicated operand; measured by the discrete-event staging model.
    job = jobs.make_atax(64, 4096)
    ops, _ = job.make_instance(0)
    pinned = AUTO.pinned(staging=Staging.HOST_FANOUT)
    fs = perflint.lint(job, ops, policy=pinned, clusters=ids8)
    assert "OFLP101" in codes(fs), codes(fs)
    fixed_pol = perflint.suggested_policy(fs, pinned)
    rep = planner.replicated_bytes(job, ops)
    record("OFLP101",
           planner.staging_cost(rep, ids8, Staging.HOST_FANOUT),
           planner.staging_cost(rep, ids8, fixed_pol.staging),
           min(planner.staging_cost(rep, ids8, m)
               for m in (Staging.HOST_FANOUT, Staging.TREE)))

    # OFLP102/OFLP103 — fine-grained batch with fuse (resp. window)
    # pinned below the model's pick; measured by the amortization model
    # over the decisions the session would actually run.
    job = jobs.make_axpy(256)
    ops, _ = job.make_instance(0)
    batch = 16

    def batch_total(policy) -> float:
        d = planner.decide(job, 8, batch, policy, 4, operands=ops)
        return batch * planner.per_job_cycles(job.spec, 8, d.fuse, d.window)

    for code, pin in (("OFLP102", {"fuse": 1}), ("OFLP103", {"window": 1})):
        pinned = AUTO.pinned(**pin)
        fs = perflint.lint(job, ops, policy=pinned, batch=batch, n=8)
        assert code in codes(fs), (code, codes(fs))
        record(code, batch_total(pinned),
               batch_total(perflint.suggested_policy(fs, pinned)),
               batch_total(AUTO))

    # OFLP104 — the reshard chain from the checked-in corpus; measured
    # by the discrete-event graph simulator.  Autofixing realigns one
    # edge per round, so apply to a fixpoint (bounded).
    job = jobs.make_axpy(2048)
    ops, _ = job.make_instance(0)
    ops = {k: np.asarray(v) for k, v in ops.items()}

    def serial(clusters_mid, clusters_tail):
        return [
            GraphNode(job, ops, name="wide"),
            GraphNode(job, {"x": ops["x"], "y": Ref("wide")}, name="narrow",
                      clusters=clusters_mid),
            GraphNode(job, {"x": ops["x"], "y": Ref("narrow")}, name="tail",
                      clusters=clusters_tail),
        ]

    def makespan(nds) -> float:
        gjobs, _ = perflint.graph_jobs(nds, default_width=8)
        return simulator.simulate_graph(gjobs).makespan

    nodes = serial([0, 1, 2, 3], None)
    fs = perflint.lint_graph(nodes, default_width=8)
    assert "OFLP104" in codes(fs), codes(fs)
    cur, fix_rounds = nodes, 0
    for _ in range(8):
        fs = perflint.lint_graph(cur, default_width=8)
        if not fs:
            break
        applied = perflint.apply(fs, nodes=cur)
        assert applied.nodes is not None
        cur = applied.nodes
        fix_rounds += 1
    record("OFLP104", makespan(nodes), makespan(cur),
           makespan(serial(None, None)))
    raw["OFLP104"]["fix_rounds"] = fix_rounds

    # OFLP105 — a misaligned 8-wide selection needing 4 multicast
    # requests; measured as the job total plus the replayed dispatch
    # constant per extra request.
    job = jobs.make_axpy(2048)
    ops, _ = job.make_instance(0)
    mis = list(range(1, 9))
    fs = perflint.lint(job, ops, clusters=mis)
    assert "OFLP105" in codes(fs), codes(fs)
    fixed_sel = perflint.apply(fs, clusters=mis).clusters
    assert fixed_sel is not None

    def sel_cost(sel) -> float:
        reqs = simulator.selection_requests(sel)
        return (amodel.predict_total_v2(job.spec, len(list(sel)), p)
                + (reqs - 1) * perflint.dispatch_replay_cycles(
                    job.spec, len(list(sel)), p))

    record("OFLP105", sel_cost(mis), sel_cost(fixed_sel),
           sel_cost(ids8))

    # OFLP106 — a staged residency never redispatched: the dead stage's
    # cycles (the session ledger's formula) are pure waste on top of the
    # dispatch the submit actually pays.
    job = jobs.make_axpy(2048)
    ops, _ = job.make_instance(0)
    total_b = sum(int(np.asarray(v).nbytes) for v in ops.values())
    rep_b = planner.replicated_bytes(job, ops)
    waste = 0.0
    if rep_b > 0:
        waste += planner.staging_cost(rep_b, ids8,
                                      planner.pick_staging(rep_b, ids8))
    if total_b > rep_b:
        waste += (p.dma_setup_one
                  + (total_b - rep_b) / p.wide_bw_bytes_per_cycle
                  + p.dma_latency)
    base = amodel.predict_total_v2(job.spec, 8, p)
    record("OFLP106", base + waste, base, base)

    # OFLP107 — donation off on a fused batch whose stacked input dies
    # at launch: each launch pays a copy of the fused output buffer.
    job = jobs.make_axpy(256)
    ops, _ = job.make_instance(0)
    batch = 16
    fs = perflint.lint(job, ops, batch=batch, n=8)
    assert "OFLP107" in codes(fs), codes(fs)
    d = planner.decide(job, 8, batch, AUTO, 4, operands=ops)
    launches = math.ceil(batch / d.fuse)
    out_b = int(np.asarray(ops["y"]).nbytes)
    copy_waste = launches * perflint.donation_copy_cycles(
        out_b * d.fuse, p)
    base = batch_total(AUTO)
    record("OFLP107", base + copy_waste, base, base)

    return rows, raw


def _corpus_rows() -> Tuple[List[Row], dict]:
    """The CI gate, as rows: zero non-baselined findings over the
    checked-in graphs (the same corpus/baseline ``make lint-graphs``
    loads)."""
    from repro import lint as lint_cli

    corpus = lint_cli.load_corpus(lint_cli.DEFAULT_CORPUS, root=_ROOT)
    results = lint_cli.lint_corpus(corpus)
    baseline = lint_cli.load_baseline(_ROOT / lint_cli.DEFAULT_BASELINE)
    fresh = lint_cli.new_findings(results, baseline)
    assert not fresh, [f"{g}: {f}" for g, f in fresh]
    total = sum(len(f) for _, f in results)
    rows = [
        ("perflint/corpus/graphs", float(len(corpus)), "graphs"),
        ("perflint/corpus/findings", float(total), "findings"),
        ("perflint/corpus/nonbaselined_findings", float(len(fresh)),
         "findings"),
    ]
    return rows, {"graphs": len(corpus), "findings": total,
                  "fresh": len(fresh)}


_OVERHEAD_CHILD = """
import json, time
import numpy as np
from repro.core import jobs
from repro.core.session import Session

job = jobs.make_axpy(16384)
ops, _ = job.make_instance(0)
sess = Session()
sess.submit(job, ops).wait()            # warm plan + compile
ITERS, REPS = 100, 5

def measure(lint):
    sess.submit(job, ops, lint=lint).wait()     # cold lint paid here
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        for _ in range(ITERS):
            sess.submit(job, ops, lint=lint).wait()
        best = min(best, (time.perf_counter() - t0) / ITERS * 1e6)
    return best

from repro.analysis import perflint
t0 = time.perf_counter()
perflint.lint(job, ops, batch=1, n=8)
cold_us = (time.perf_counter() - t0) * 1e6

off_us = measure(False)
on_us = measure(True)
print(json.dumps({"cold_us": cold_us, "off_us": off_us, "on_us": on_us}))
"""


def _overhead_rows() -> Tuple[List[Row], dict]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_ENABLE_X64"] = "true"
    env["PYTHONPATH"] = (str(_ROOT / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_OVERHEAD_CHILD)],
        capture_output=True, text=True, env=env, timeout=570)
    if proc.returncode != 0:
        raise RuntimeError(f"overhead subprocess failed: "
                           f"{proc.stderr[-400:]}")
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    pct = (data["on_us"] - data["off_us"]) / data["off_us"] * 100.0
    assert pct < OVERHEAD_BAR_PCT, (data, pct)
    rows = [
        ("perflint/lint/cold_us", data["cold_us"], "us"),
        ("perflint/lint/warm_submit_us", data["off_us"], "us"),
        ("perflint/lint/warm_submit_lint_us", data["on_us"], "us"),
        ("perflint/lint/overhead_pct", pct, "percent"),
    ]
    return rows, dict(data, overhead_pct=pct)


def perflint_suite() -> Tuple[List[Row], str]:
    rows, raw = _regret_rows()
    crows, craw = _corpus_rows()
    orows, oraw = _overhead_rows()
    rows += crows + orows
    worst = max(v for n, v, _ in rows if n.endswith("regret_fixed"))
    derived = (f"autofixed regret <= {worst:.3f} (bar {REGRET_BAR}) on "
               f"{len(raw)} seeded codes; corpus {craw['fresh']} "
               f"non-baselined finding(s); lint overhead "
               f"{oraw['overhead_pct']:+.2f}% (< {OVERHEAD_BAR_PCT}%)")
    perflint_suite.last_raw = {"regret": raw, "corpus": craw,
                               "overhead": oraw}
    return rows, derived


perflint_suite.last_raw = {}
