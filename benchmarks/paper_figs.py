"""Reproductions of every paper table/figure, one function per figure.

Each returns (csv_rows, derived_summary).  ``benchmarks.run`` prints them as
``name,us_per_call,derived`` CSV — for the simulator-backed figures the
"us_per_call" column carries the modeled cycles (1 cycle = 1 ns at the
paper's 1 GHz), and "derived" the figure's headline statistic.
"""

from __future__ import annotations

import statistics
from typing import Dict, List, Tuple

import numpy as np

from repro.core import jobs, model, simulator
from repro.core.phases import Phase

NS = (1, 2, 4, 8, 16, 32)
Row = Tuple[str, float, str]


def fig07_overhead() -> Tuple[List[Row], str]:
    """Fig. 7: offload overhead vs number of clusters, per application."""
    rows: List[Row] = []
    at32 = []
    at1 = []
    for name, mk in jobs.PAPER_JOBS.items():
        spec = mk().spec
        for n in NS:
            ov = simulator.offload_overhead(spec, n, "baseline")
            rows.append((f"fig07/{name}/n={n}", ov, "overhead_cycles"))
            if n == 32:
                at32.append(ov)
            if n == 1:
                at1.append(ov)
    derived = (f"avg@1={statistics.mean(at1):.0f}cyc(paper 242) "
               f"max@32={max(at32):.0f}cyc(paper 1146) "
               f"std@32={statistics.pstdev(at32):.0f}(paper 256)")
    return rows, derived


def fig08_speedup_restoration() -> Tuple[List[Row], str]:
    """Fig. 8: ideal vs achieved speedup; restoration fraction."""
    rows: List[Row] = []
    restored = []
    for name, mk in jobs.PAPER_JOBS.items():
        spec = mk().spec
        for n in NS[1:]:
            s_ideal, s_ext, rest = simulator.speedups(spec, n)
            rows.append((f"fig08/{name}/n={n}/ideal", s_ideal, "speedup"))
            rows.append((f"fig08/{name}/n={n}/achieved", s_ext, "speedup"))
            restored.append(rest)
    derived = (f"restoration min={min(restored)*100:.0f}% "
               f"max={max(restored)*100:.0f}% (paper: 70-96%)")
    return rows, derived


def fig09_runtime_curves() -> Tuple[List[Row], str]:
    """Fig. 9: base/ideal/improved runtimes for AXPY and ATAX."""
    rows: List[Row] = []
    for label, spec in (("axpy", jobs.axpy_spec(1024)),
                        ("atax", jobs.atax_spec(64, 64))):
        for mode in ("baseline", "ideal", "multicast"):
            for n in NS:
                t = simulator.simulate(spec, n, mode).total
                rows.append((f"fig09/{label}/{mode}/n={n}", t, "cycles"))
    base = [simulator.simulate(jobs.axpy_spec(1024), n, "baseline").total for n in NS]
    ext = [simulator.simulate(jobs.axpy_spec(1024), n, "multicast").total for n in NS]
    has_min = min(base) < base[-1]
    mono = all(b > a for a, b in zip(ext[1:], ext[:-1]))
    derived = (f"axpy baseline interior minimum={has_min}(paper True) "
               f"multicast monotone decreasing={mono}(paper True)")
    return rows, derived


def fig10_weak_scaling() -> Tuple[List[Row], str]:
    """Fig. 10: multicast-over-baseline speedup across problem sizes, with
    fixed work per cluster (weak scaling)."""
    rows: List[Row] = []
    speedups = []
    for per_cluster in (64, 128, 512):
        for n in (2, 8, 32):
            spec = jobs.axpy_spec(per_cluster * n)
            s = (simulator.simulate(spec, n, "baseline").total
                 / simulator.simulate(spec, n, "multicast").total)
            rows.append((f"fig10/axpy/perc={per_cluster}/n={n}", s, "speedup"))
            speedups.append(s)
            spec = jobs.atax_spec(per_cluster, per_cluster)
            s = (simulator.simulate(spec, n, "baseline").total
                 / simulator.simulate(spec, n, "multicast").total)
            rows.append((f"fig10/atax/M={per_cluster}/n={n}", s, "speedup"))
            speedups.append(s)
    derived = (f"all speedups > 1: {all(s > 1.0 for s in speedups)} "
               f"(paper: 'speedup greater than one in all experiments'); "
               f"max={max(speedups):.2f}x (paper <=2.3x)")
    return rows, derived


def fig11_phase_breakdown() -> Tuple[List[Row], str]:
    """Fig. 11: per-phase min/avg/max runtimes of an AXPY-1024 offload."""
    rows: List[Row] = []
    spec = jobs.axpy_spec(1024)
    for mode in ("baseline", "multicast"):
        for n in NS:
            stats = simulator.simulate(spec, n, mode).phase_stats()
            for ph, s in sorted(stats.items(), key=lambda kv: kv[0].name):
                rows.append(
                    (f"fig11/{mode}/n={n}/{ph.name}/avg", s.avg, "cycles"))
                rows.append(
                    (f"fig11/{mode}/n={n}/{ph.name}/max", s.max, "cycles"))
    b32 = simulator.simulate(spec, 32, "baseline").phase_stats()
    m32 = simulator.simulate(spec, 32, "multicast").phase_stats()
    derived = (f"wakeup@32 base_max={b32[Phase.B].max:.0f}cyc "
               f"mc={m32[Phase.B].max:.0f}cyc(paper 47); "
               f"E_max mc={m32[Phase.E].max:.0f}cyc"
               f"(eq.1: {53 + 55 + 2 * 1024 * 8 / 64:.0f})")
    return rows, derived


def fig12_model_error() -> Tuple[List[Row], str]:
    """Fig. 12: relative error of the analytical model across sizes/n."""
    rows: List[Row] = []
    errs_v1: List[float] = []
    errs_v2: List[float] = []
    cases = {
        "axpy": (jobs.axpy_spec, [(64,), (128,), (256,), (512,), (1024,)]),
        "atax": (jobs.atax_spec, [(32, 32), (64, 64), (128, 128), (512, 512)]),
        "matmul": (lambda s: jobs.matmul_spec(s, s, s), [(8,), (16,), (32,), (64,)]),
        "covariance": (lambda s: jobs.covariance_spec(s, 2 * s), [(16,), (32,), (64,)]),
        "montecarlo": (jobs.montecarlo_spec, [(4096,), (16384,), (65536,)]),
        "bfs": (jobs.bfs_spec, [(64,), (256,), (1024,)]),
    }
    for name, (mk, sizes) in cases.items():
        pts = model.validate(mk, sizes, NS)
        err = model.max_rel_error(pts)
        errs_v1.append(err)
        rows.append((f"fig12/{name}/max_rel_err_v1", err * 100, "percent"))
        pts2 = model.validate(mk, sizes, NS, predictor=model.predict_total_v2)
        err2 = model.max_rel_error(pts2)
        errs_v2.append(err2)
        rows.append((f"fig12/{name}/max_rel_err_v2", err2 * 100, "percent"))
    derived = (f"v1 max={max(errs_v1)*100:.1f}% (paper <15%); "
               f"v2(beyond-paper) max={max(errs_v2)*100:.1f}%")
    return rows, derived


def table_offload_decision() -> Tuple[List[Row], str]:
    """§5.6: model-driven offload decisions (optimal cluster counts)."""
    rows: List[Row] = []
    picks = {}
    for N in (64, 256, 1024, 8192, 65536):
        n, t = model.optimal_clusters(lambda: jobs.axpy_spec(N))
        rows.append((f"decision/axpy/N={N}", n, f"pred={t:.0f}cyc"))
        picks[N] = n
    derived = f"optimal n grows with N: {picks}"
    return rows, derived


ALL_FIGS = {
    "fig07": fig07_overhead,
    "fig08": fig08_speedup_restoration,
    "fig09": fig09_runtime_curves,
    "fig10": fig10_weak_scaling,
    "fig11": fig11_phase_breakdown,
    "fig12": fig12_model_error,
    "decision": table_offload_decision,
}
