"""Fabric-scheduler contract — deterministic, part of the CI subset.

Three claims of the PR-5 multi-tenant scheduler (`repro.core.fabric`),
pinned numerically against the discrete-event fabric model
(`repro.core.simulator.simulate_fabric`):

* **utilization** — on the mixed-tenant scenario (a resident serve
  tenant plus three bursty offload tenants on disjoint 8-cluster
  leases), the scheduled fabric achieves >= 1.5x the useful-work
  utilization of serialized whole-mesh dispatch (each tenant owning all
  32 clusters, one job at a time — the pre-scheduler operating point).
  The suite asserts the bar itself, so a scheduler regression fails the
  run even before ``--check`` compares the recorded rows.

* **placement regret** — the scheduler's greedy, model-scored placement
  (quadrant-aware staging cost per candidate window) stays within 1.05x
  of the exhaustive joint optimum over every feasible contiguous
  placement on small grids, including pre-fragmented ones.  A
  ``first_fit`` baseline row shows what the model buys (it straddles
  quadrants where the model does not).

* **makespan model** — the closed-form multi-tenant makespan
  (`fabric_makespan_model`) predicts the discrete-event makespan within
  the paper's §6 accuracy bar; the ``model_error`` rows feed the
  harness's hard <15 % check.

Pure model arithmetic — no devices, no wallclock noise; safe to gate CI.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from repro.core import jobs, simulator
from repro.core.fabric import FabricScheduler, SchedulerPolicy, Tenant
from repro.core.params import OccamyParams
from repro.core.policy import TenantKind
from repro.core.session import Planner
from repro.core.simulator import (
    TenantWorkload, fabric_makespan_model, simulate_fabric,
)

Row = Tuple[str, float, str]

#: acceptance bars (ISSUE-5): asserted by the suite itself
UTILIZATION_BAR = 1.5
REGRET_BAR = 1.05

#: the mixed-tenant scenario: one resident serve tenant + three bursty
#: offload tenants, 16 jobs each, on quarter-fabric leases
MIXED_TENANTS = (
    ("serve", TenantKind.SERVE, lambda: jobs.make_matmul(16, 16, 16)),
    ("axpy", TenantKind.OFFLOAD, lambda: jobs.make_axpy(1024)),
    ("cov", TenantKind.OFFLOAD, lambda: jobs.make_covariance(32, 64)),
    ("atax", TenantKind.OFFLOAD, lambda: jobs.make_atax(64, 64)),
)
MIXED_JOBS = 16
MIXED_LEASE = 8


def _mixed_scenario() -> Tuple[List[Row], float]:
    rows: List[Row] = []
    sched = FabricScheduler(num_clusters=32)
    workloads = []
    for name, kind, mk in MIXED_TENANTS:
        job = mk()
        lease = sched.request(Tenant(name, kind=kind), n=MIXED_LEASE,
                              job=job)
        workloads.append(TenantWorkload(name, job.spec, lease.clusters,
                                        jobs=MIXED_JOBS))
    measured = simulate_fabric(workloads)
    predicted = fabric_makespan_model(workloads)
    err = simulator.model_error(predicted, measured.makespan)

    # the pre-scheduler baseline: every tenant owns the whole mesh, jobs
    # strictly serialized (window=1, one shared lease)
    full = tuple(range(32))
    serial = [TenantWorkload(w.tenant, w.spec, full, jobs=w.jobs, window=1)
              for w in workloads]
    measured_s = simulate_fabric(serial)
    predicted_s = fabric_makespan_model(serial)
    err_s = simulator.model_error(predicted_s, measured_s.makespan)

    util = measured.utilization(32)
    util_s = measured_s.utilization(32)
    ratio = util / util_s
    assert ratio >= UTILIZATION_BAR, (
        f"fabric utilization ratio {ratio:.2f} below the "
        f"{UTILIZATION_BAR}x acceptance bar (scheduled "
        f"{measured.makespan:.0f} cyc vs serialized "
        f"{measured_s.makespan:.0f} cyc)")
    rows += [
        ("scheduler/mixed/makespan", measured.makespan, "cycles"),
        ("scheduler/mixed/predicted", predicted, "cycles"),
        ("scheduler/mixed/model_error", err * 100, "percent"),
        ("scheduler/serialized/makespan", measured_s.makespan, "cycles"),
        ("scheduler/serialized/predicted", predicted_s, "cycles"),
        ("scheduler/serialized/model_error", err_s * 100, "percent"),
        ("scheduler/mixed/utilization_ratio", ratio, "ratio"),
    ]
    return rows, ratio


#: small-grid placement scenarios: (name, busy clusters, request sizes);
#: 8-cluster fabric of two quadrants — fragmentation forces real choices
PLACEMENT_GRID = OccamyParams(num_quadrants=2)
PLACEMENT_SCENARIOS = (
    ("clean", (), (4, 2, 2)),
    ("fragmented", (0, 1), (4, 2)),
    ("holed", (2,), (4, 2)),
)


def _staging_cost(window: Sequence[int], nbytes: int,
                  params: OccamyParams) -> float:
    return simulator.simulate_staging(max(1, nbytes), list(window), "tree",
                                      params)


def _exhaustive_best(requests: Sequence[Tuple[int, int]], busy: Sequence[int],
                     params: OccamyParams) -> float:
    """Joint optimum of the placement-sensitive objective: total staging
    cost over every feasible assignment of disjoint contiguous windows."""
    num = params.num_clusters
    free = set(range(num)) - set(busy)
    best = [float("inf")]

    def rec(i: int, cost: float, taken: frozenset) -> None:
        if cost >= best[0]:
            return
        if i == len(requests):
            best[0] = cost
            return
        n, nbytes = requests[i]
        for s in range(num - n + 1):
            window = range(s, s + n)
            if all(c in free and c not in taken for c in window):
                rec(i + 1, cost + _staging_cost(window, nbytes, params),
                    taken | frozenset(window))

    rec(0, 0.0, frozenset())
    return best[0]


def _placement_rows() -> Tuple[List[Row], float]:
    rows: List[Row] = []
    job = jobs.make_covariance(32, 64)          # broadcast-class operands
    nbytes = Planner(PLACEMENT_GRID).replicated_bytes(job)
    worst = 1.0
    for name, busy, sizes in PLACEMENT_SCENARIOS:
        requests = [(n, nbytes) for n in sizes]
        chosen_cost: Dict[str, float] = {}
        for placement in ("model", "first_fit"):
            # the naive baseline drops the alignment preference too — it
            # is what a scheduler without the cost model would do
            sched = FabricScheduler(
                num_clusters=PLACEMENT_GRID.num_clusters,
                params=PLACEMENT_GRID,
                policy=SchedulerPolicy(placement=placement,
                                       align=placement == "model"))
            if busy:
                sched.request("busy", clusters=list(busy))
            cost = 0.0
            for k, n in enumerate(sizes):
                lease = sched.request(f"t{k}", n=n, job=job)
                cost += _staging_cost(lease.clusters, nbytes,
                                      PLACEMENT_GRID)
            chosen_cost[placement] = cost
        best = _exhaustive_best(requests, busy, PLACEMENT_GRID)
        regret = chosen_cost["model"] / best
        worst = max(worst, regret)
        assert regret <= REGRET_BAR, (
            f"placement regret {regret:.3f} on {name!r} above the "
            f"{REGRET_BAR} acceptance bar")
        rows.append((f"scheduler/placement/{name}/regret", regret, "ratio"))
        rows.append((f"scheduler/placement/{name}/first_fit_vs_model",
                     chosen_cost["first_fit"] / chosen_cost["model"],
                     "ratio"))
    return rows, worst


def _slice_rows() -> List[Row]:
    """The model-driven slice sizes (admission signature, exact rows)."""
    rows: List[Row] = []
    for name, mk, batch in (("axpy1024", lambda: jobs.make_axpy(1024), 16),
                            ("matmul64", lambda: jobs.make_matmul(64, 64, 64),
                             16)):
        sched = FabricScheduler(num_clusters=32)
        lease = sched.request("t", job=mk(), batch=batch)
        rows.append((f"scheduler/slice/{name}/n", float(lease.n),
                     "clusters"))
    return rows


def scheduler_suite() -> Tuple[List[Row], str]:
    rows, ratio = _mixed_scenario()
    placement, worst_regret = _placement_rows()
    rows += placement
    rows += _slice_rows()
    errs = [v for n, v, u in rows if "model_error" in n]
    derived = (
        f"mixed-tenant utilization {ratio:.2f}x over serialized whole-mesh "
        f"dispatch (bar {UTILIZATION_BAR}x); placement regret "
        f"{worst_regret:.3f} vs exhaustive search over "
        f"{len(PLACEMENT_SCENARIOS)} small-grid scenarios (bar "
        f"{REGRET_BAR}); makespan model error max {max(errs):.2f}% "
        "(paper bar <15%)")
    return rows, derived
