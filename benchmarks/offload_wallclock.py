"""Real-runtime benchmark: baseline vs multicast offload dispatch on an
8-device CPU mesh (subprocess, so the bench process keeps 1 device), plus
the HLO collective structure — the measurable, hardware-independent
signature of the paper's co-design."""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from typing import List, Tuple

Row = Tuple[str, float, str]

_CHILD = """
import json, time
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadRuntime, OffloadConfig, count_collectives

job = jobs.make_axpy(4096)
operands, _ = job.make_instance(0)
out = {}
for label, cfg in (("multicast", OffloadConfig.extended()),
                   ("baseline", OffloadConfig.baseline())):
    rt = OffloadRuntime(config=cfg)
    rt.offload(job, operands, n=8).wait()          # compile + warm
    t0 = time.perf_counter()
    iters = 30
    for _ in range(iters):
        rt.offload(job, operands, n=8).wait()
    us = (time.perf_counter() - t0) / iters * 1e6
    colls = count_collectives(rt.lowered_text(job, 8))
    out[label] = {"us": us, "collectives": colls}
print(json.dumps(out))
"""


def offload_wallclock() -> Tuple[List[Row], str]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_ENABLE_X64"] = "true"
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(_CHILD)],
                          capture_output=True, text=True, env=env, timeout=600)
    if proc.returncode != 0:
        return [("offload/error", 0.0, proc.stderr[-200:])], "subprocess failed"
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    rows = [
        ("offload/axpy4096/multicast/8dev", data["multicast"]["us"], "us"),
        ("offload/axpy4096/baseline/8dev", data["baseline"]["us"], "us"),
    ]
    mc_c = data["multicast"]["collectives"]
    bl_c = data["baseline"]["collectives"]
    rows.append(("offload/multicast/chain_depth",
                 mc_c["collective-permute"], "collective-permutes"))
    rows.append(("offload/baseline/chain_depth",
                 bl_c["collective-permute"], "collective-permutes"))
    derived = (f"baseline chain = {bl_c['collective-permute']} ppermutes "
               f"(= 2(n-1)); multicast = {mc_c['all-reduce']} all-reduce; "
               f"wallclock ratio {data['baseline']['us']/data['multicast']['us']:.2f}x")
    return rows, derived
