"""Real-runtime benchmark of the framework's *own* offload overheads.

Subprocess-isolated measurements (the bench process keeps 1 device):

* **dispatch sweep** (``offload_wallclock``) — for n ∈ {1, 2, 4, 8}
  clusters, the host-side dispatch overhead of ``OffloadRuntime.offload()``
  (time to launch, excluding the blocking wait) in three regimes:

    - ``cold``      first dispatch: plan build + compile + staging
    - ``warm``      warm plan, operands re-``device_put`` each job (the
                    seed's re-staging path)
    - ``resident``  warm plan, resident operands — zero ``device_put``

  plus the end-to-end µs/job and, at n=8, the baseline-vs-multicast
  wallclock and HLO collective structure (the paper's fig.-7 signature),
  and µs/token of ``ServeEngine`` for the legacy host round-trip loop vs
  the device-resident single-step and ``lax.scan`` chunk paths.

* **stream suite** (``stream_wallclock``) — jobs/s through the one
  ``Session.submit`` path under typed policies: sequential resident
  dispatch (``fuse=1, window=1``) vs the pipelined window in both modes —
  resident redispatch (same data movement as sequential, so the delta is
  launch+fetch hidden behind compute) and fresh staging per job (the slot
  double-buffer overlapping phase E with compute, against the sequential
  re-staging baseline) — vs fused dispatch batching at B ∈ {1, 2, 4, 8}
  (per-job share of one batched launch), with the fused HLO collective
  counts at B=2 vs B=8 (must not grow with B).  ``policy=AUTO`` rows
  record what the model-driven planner picks and what it measures —
  the acceptance surface for "AUTO is never slower than the best
  hand-picked mode" (asserted against this recording by
  ``tests/test_session.py``).

* **serve-throughput suite** (``serve_throughput``) — tokens/s of static
  fixed-batch ``generate`` calls vs continuous-batching ``generate_many``
  under a Poisson-ish arrival trace of variable-length prompts.

* **staging sweep** (``staging_wall``) — real-runtime phase-E staging of a
  32 MiB replicated operand through ``DispatchPlan.stage`` for n ∈
  {1, 2, 4, 8} clusters, ``host_fanout`` (the O(n) sequential host-link
  baseline) vs ``tree`` (hierarchical broadcast staging), cold and warm,
  with the exact ``h2d_bytes``/``d2d_bytes`` counters per point.  A
  :class:`~repro.core.simulator.StagingCostModel` is calibrated from the
  warm host-fanout n ∈ {1, 2} and tree n=4 points and its predictions are
  recorded against every measurement as ``model_residual`` rows.  These
  rows are deliberately *not* named ``model_error``: the CPU test
  substrate's host link is parallel and cache-dominated (copies of a hot
  source can be near-free, device-to-device transfers take an unoptimized
  path), so wallclock residuals carry tens of percent of machine noise —
  the paper's <15 % bar is enforced where its serial-link premise holds,
  on the deterministic ``staging`` suite's ``model_error`` rows
  (``benchmarks/staging.py``, wired into CI).

Each suite returns printable rows; the raw nested dict is kept on the
function's ``last_raw`` for ``benchmarks/run.py --json``.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from typing import List, Tuple

Row = Tuple[str, float, str]

_DISPATCH_CHILD = """
import json, statistics, time
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadRuntime, OffloadConfig, count_collectives
from repro.core.policy import Residency

# Large-enough operands that phase-E staging is a real cost (the paper's
# fine-grained regime is the *ratio* of overhead to work, not tiny data).
job = jobs.make_axpy(16384)
operands, _ = job.make_instance(0)
ITERS = 60
out = {"sweep": {}}

def median_dispatch(fn, iters):
    # dispatch-only: time offload() (async launch), wait outside the timer;
    # medians — CPU-mesh collectives make per-call means noisy
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        h = fn()
        ts.append(time.perf_counter() - t0)
        h.wait()
    return statistics.median(ts) * 1e6

def median_e2e(fn, iters):
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn().wait()
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts) * 1e6

for n in (1, 2, 4, 8):
    rt = OffloadRuntime(config=OffloadConfig.extended())
    t0 = time.perf_counter()
    rt.offload(job, operands, n=n).wait()
    cold_us = (time.perf_counter() - t0) * 1e6
    warm_us = median_dispatch(lambda: rt.offload(job, operands, n=n), ITERS)
    warm_e2e_us = median_e2e(lambda: rt.offload(job, operands, n=n), ITERS)
    resident_us = median_dispatch(
        lambda: rt.offload(job, Residency.RESIDENT, n=n), ITERS)
    resident_e2e_us = median_e2e(
        lambda: rt.offload(job, Residency.RESIDENT, n=n), ITERS)
    out["sweep"][str(n)] = {
        "cold_us": cold_us,
        "warm_dispatch_us": warm_us,
        "resident_dispatch_us": resident_us,
        "warm_e2e_us": warm_e2e_us,
        "resident_e2e_us": resident_e2e_us,
        "recompiles_after_warm": len(rt._compiled) - 1,
    }

cmp = {}
for label, cfg in (("multicast", OffloadConfig.extended()),
                   ("baseline", OffloadConfig.baseline())):
    rt = OffloadRuntime(config=cfg)
    rt.offload(job, operands, n=8).wait()          # compile + warm
    cmp[label] = {
        "us": median_e2e(lambda: rt.offload(job, operands, n=8), 30),
        "collectives": count_collectives(rt.lowered_text(job, 8)),
    }
out["compare"] = cmp
print(json.dumps(out))
"""

_SERVE_CHILD = """
import json, time
import jax, numpy as np
from jax.sharding import Mesh
from repro import models as M
from repro.dist.sharding import param_specs, to_shardings
from repro.serve import ServeConfig, ServeEngine

cfg = M.reduced(M.get("smollm-360m"))
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
params = M.init_params(jax.random.key(0), cfg)
params = jax.device_put(params, to_shardings(param_specs(params, mesh), mesh))
prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (4, 12)).astype(np.int32)

N_NEW = 48
out = {}
for mode in ("host", "step", "chunk"):
    eng = ServeEngine(cfg, params, mesh,
                      ServeConfig(batch=4, max_len=80, decode_mode=mode,
                                  decode_chunk=8))
    eng.generate(prompts, N_NEW)                    # compile + warm
    base = dict(eng.stats)
    t0 = time.perf_counter()
    toks = eng.generate(prompts, N_NEW)
    dt = time.perf_counter() - t0
    out[mode] = {
        "us_per_token": dt / N_NEW * 1e6,
        "h2d_token_puts_per_step": (eng.stats["h2d_token_puts"]
                                    - base["h2d_token_puts"]) / N_NEW,
        "dispatches": eng.stats["xla_dispatches"] - base["xla_dispatches"],
    }
print(json.dumps(out))
"""


_STREAM_CHILD = """
import json, statistics, time
import numpy as np
from repro.api import AUTO, OffloadPolicy, Residency, Session
from repro.core import jobs
from repro.core.offload import count_collectives

# Stream measurement wants the t_compute > t_stage + t_dispatch regime,
# where pipelining hides the whole per-job host cost behind compute (the
# amortization model's max(t_stage, t_compute) term): a mid-size matmul.
# Every mode is a typed policy through the one Session.submit path; the
# legacy hand-picked modes pin their knobs, `auto` lets the planner pick.
job = jobs.make_matmul(256, 256, 256)
N_JOBS = 32
REPEATS = 8
insts, _ = jobs.make_instances(job, 8, seed0=0)
out = {}

SEQ = OffloadPolicy(fuse=1, window=1)
PIPE = OffloadPolicy(fuse=1)                   # window -> planner (n_units)
sess = Session(n_units=4)
sess.submit(job, insts[0], n=8, policy=SEQ).wait()   # warm plan + compile
sess.stage(job, insts[0], n=8)                       # prime residency

def seq_resident():
    for _ in range(N_JOBS):
        sess.submit(job, Residency.RESIDENT, n=8, policy=SEQ).wait()

def seq_restage():
    for i in range(N_JOBS):
        sess.submit(job, insts[i % 8], n=8, policy=SEQ).wait()

# warm the pipelined slot path (and its estimate cache)
sess.submit(job, insts[:4], n=8, policy=PIPE).wait()

def pipelined():
    handles = [sess.submit(job, insts[i % 8], n=8, policy=PIPE)
               for i in range(N_JOBS)]
    for h in handles:
        h.wait()

def pipelined_resident():
    # same data movement as seq_resident (none): isolates what the
    # in-flight window buys — launch+fetch hidden behind compute
    handles = [sess.submit(job, Residency.RESIDENT, n=8, policy=PIPE)
               for _ in range(N_JOBS)]
    for h in handles:
        h.wait()

# AUTO: one list submit, the planner picks fuse/window/staging from the
# cost models (fused launches pipelined through the window)
auto_work = [insts[i % 8] for i in range(N_JOBS)]
auto_handle = sess.submit(job, auto_work, n=8)       # compile + warm
auto_handle.wait()
auto_decision = auto_handle.decision

def auto_submit():
    sess.submit(job, auto_work, n=8).wait()

# Round-robin measurement: this substrate's throughput drifts over the
# child's lifetime (a small CPU share under an 8-device mesh), so timing
# each mode in its own block would bias whichever runs first.  Interleave
# one run of every mode per round and keep each mode's best round.
modes = {
    "seq_resident": seq_resident,
    "seq_restage": seq_restage,
    "pipelined": pipelined,
    "pipelined_resident": pipelined_resident,
    "auto": auto_submit,
}
best = {k: 0.0 for k in modes}
for _ in range(REPEATS):
    for k, fn in modes.items():
        t0 = time.perf_counter()
        fn()
        best[k] = max(best[k], N_JOBS / (time.perf_counter() - t0))

out["stream"] = {
    "seq_resident_jobs_s": best["seq_resident"],
    "seq_restage_jobs_s": best["seq_restage"],
    "pipelined_jobs_s": best["pipelined"],
    "pipelined_resident_jobs_s": best["pipelined_resident"],
    "auto_jobs_s": best["auto"],
    "auto_decision": {"fuse": auto_decision.fuse,
                      "window": auto_decision.window,
                      "staging": auto_decision.staging.value},
    "window": auto_decision.window,
}

# fused dispatch batching: per-job share of one batched launch.  The
# fine-grained regime (tiny job, dispatch floor dominates) is where
# fusing pays — the paper's axpy.
job = jobs.make_axpy(16384)
insts, _ = jobs.make_instances(job, 8, seed0=0)
sf = Session()
sf.stage(job, insts[0], n=8)
res_ts = []
for _ in range(60):
    t0 = time.perf_counter()
    h = sf.submit(job, Residency.RESIDENT, n=8, policy=OffloadPolicy(window=1))
    res_ts.append(time.perf_counter() - t0)
    h.wait()
# least-interference samples: this substrate's 8-device mesh oversubscribes
# a small CPU share, so medians still carry scheduler spikes (same practice
# as the staging_wall suite)
resident_single_us = min(res_ts) * 1e6

fused = {}
for B in (1, 2, 4, 8):
    if B == 1:
        # B=1 is the unfused resident dispatch (the amortization anchor)
        polB = OffloadPolicy(window=1)
    else:
        bi, _ = jobs.make_instances(job, B, seed0=0)
        polB = OffloadPolicy(fuse=B, window=1)
        sf.stage(job, bi, n=8)                 # compile + stage fused batch
    ts = []
    for _ in range(40):
        t0 = time.perf_counter()
        h = sf.submit(job, Residency.RESIDENT, n=8, policy=polB)
        ts.append((time.perf_counter() - t0) / B)
        h.wait()
    fused[str(B)] = {"dispatch_us_per_job": min(ts) * 1e6}

rtf = sf.runtime()
out["fused"] = {
    "resident_single_dispatch_us": resident_single_us,
    "per_job": fused,
    "auto_fuse_pick": sf.estimate(job, batch=8, n=8).decision.fuse,
    "collectives_B2": count_collectives(rtf.lowered_text(job, 8, fuse=2)),
    "collectives_B8": count_collectives(rtf.lowered_text(job, 8, fuse=8)),
}
print(json.dumps(out))
"""

_CONT_SERVE_CHILD = """
import json, time
import jax, numpy as np
from jax.sharding import Mesh
from repro import models as M
from repro.dist.sharding import param_specs, to_shardings
from repro.serve import ServeConfig, ServeEngine

cfg = M.reduced(M.get("smollm-360m"))
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
params = M.init_params(jax.random.key(0), cfg)
params = jax.device_put(params, to_shardings(param_specs(params, mesh), mesh))

BATCH, N_NEW, R = 4, 16, 6
rng = np.random.default_rng(0)
lens = [6, 10, 14, 8, 12, 6][:R]
reqs = [(rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32), N_NEW)
        for s in lens]
# Poisson-ish arrival trace: exponential-ish integer gaps, ~1 every 2 steps
arrivals = np.cumsum(rng.poisson(2.0, size=R))
arrivals = (arrivals - arrivals[0]).tolist()

scfg = ServeConfig(batch=BATCH, max_len=48, prefill_bucket=8)
out = {}

# continuous batching: slots refill from the queue as requests retire
eng = ServeEngine(cfg, params, mesh, scfg)
eng.generate_many(reqs, arrival_steps=arrivals)          # compile + warm
base = dict(eng.stats)
t0 = time.perf_counter()
outs = eng.generate_many(reqs, arrival_steps=arrivals)
dt = time.perf_counter() - t0
total = sum(len(o) for o in outs)
out["continuous"] = {
    "tok_s": total / dt,
    "us_per_token": dt / total * 1e6,
    "dispatches": eng.stats["xla_dispatches"] - base["xla_dispatches"],
    "inserts": eng.stats["prefill_inserts"] - base["prefill_inserts"],
}

# static batching: fixed-shape generate per group of BATCH (last group
# padded through the sub-batch path), prompts right-padded to group max
eng2 = ServeEngine(cfg, params, mesh, scfg)
groups = [list(range(i, min(i + BATCH, R))) for i in range(0, R, BATCH)]
def run_static():
    n = 0
    for g in groups:
        smax = max(lens[r] for r in g)
        prompts = np.zeros((len(g), smax), np.int32)
        for k, r in enumerate(g):
            prompts[k, :lens[r]] = reqs[r][0]
        n += eng2.generate(prompts, N_NEW).size
    return n
run_static()                                             # compile + warm
t0 = time.perf_counter()
total_static = run_static()
dt = time.perf_counter() - t0
out["static"] = {
    "tok_s": total_static / dt,
    "us_per_token": dt / total_static * 1e6,
}
print(json.dumps(out))
"""


_STAGING_CHILD = """
import json, time
import jax, numpy as np
from repro.core import jobs
from repro.core.offload import OffloadRuntime
from repro.core.policy import Staging

# One big replicated operand (the covariance data matrix, broadcast class):
# 32 MiB stays bandwidth-bound — well past the cache sizes below which this
# substrate's host "link" degenerates into near-free hot-cache copies.
M_, N_ = 512 * 32, 256
job = jobs.make_covariance(M_, N_)
operands = {"data": np.random.default_rng(0).standard_normal((M_, N_))}
SIZE = operands["data"].nbytes
ITERS = 11
rt = OffloadRuntime()
out = {"size_bytes": SIZE, "sweep": {}}

for n in (1, 2, 4, 8):
    plan = rt.plan(job, operands, n=n)
    entry = {}
    for mode in ("host_fanout", "tree"):
        h0, d0 = plan.stats.h2d_bytes, plan.stats.d2d_bytes
        ts = []
        cold_ms = None
        for i in range(ITERS + 1):
            t0 = time.perf_counter()
            staged = plan.stage(operands, via=Staging(mode))
            jax.block_until_ready(list(staged.values()))
            dt = (time.perf_counter() - t0) * 1e3
            if i == 0:
                cold_ms = dt
            else:
                ts.append(dt)
            # drop the buffers between iterations: a flat memory profile,
            # so late sweep points don't pay allocator pressure the early
            # ones dodged (and the byte counters stay per-call exact)
            del staged
            plan.invalidate()
        h2d = (plan.stats.h2d_bytes - h0) // (ITERS + 1)
        d2d = (plan.stats.d2d_bytes - d0) // (ITERS + 1)
        entry[mode] = {
            "cold_ms": cold_ms,
            "warm_ms": min(ts),   # least-interference sample on a noisy VM
            "h2d_bytes": h2d,
            "d2d_bytes": d2d,
        }
    out["sweep"][str(n)] = entry
print(json.dumps(out))
"""


def _run_child(code: str, timeout: int = 570, x64: bool = True) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    # paper jobs are float64; the serving model stack is 32-bit only
    env["JAX_ENABLE_X64"] = "true" if x64 else "false"
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"bench subprocess failed: {proc.stderr[-400:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def offload_wallclock() -> Tuple[List[Row], str]:
    rows: List[Row] = []
    raw = {}

    data = _run_child(_DISPATCH_CHILD)
    raw["dispatch"] = data
    for n, d in sorted(data["sweep"].items(), key=lambda kv: int(kv[0])):
        rows.append((f"offload/axpy/{n}dev/cold", d["cold_us"], "us"))
        rows.append((f"offload/axpy/{n}dev/warm_dispatch",
                     d["warm_dispatch_us"], "us/job"))
        rows.append((f"offload/axpy/{n}dev/resident_dispatch",
                     d["resident_dispatch_us"], "us/job"))
        rows.append((f"offload/axpy/{n}dev/warm_e2e",
                     d["warm_e2e_us"], "us/job"))
        rows.append((f"offload/axpy/{n}dev/resident_e2e",
                     d["resident_e2e_us"], "us/job"))
    cmp = data["compare"]
    rows.append(("offload/axpy/multicast/8dev", cmp["multicast"]["us"], "us"))
    rows.append(("offload/axpy/baseline/8dev", cmp["baseline"]["us"], "us"))
    rows.append(("offload/multicast/chain_depth",
                 cmp["multicast"]["collectives"]["collective-permute"],
                 "collective-permutes"))
    rows.append(("offload/baseline/chain_depth",
                 cmp["baseline"]["collectives"]["collective-permute"],
                 "collective-permutes"))

    serve_note = ""
    try:
        serve = _run_child(_SERVE_CHILD, x64=False)
        raw["serve"] = serve
        for mode, d in serve.items():
            rows.append((f"serve/decode/{mode}", d["us_per_token"], "us/token"))
            rows.append((f"serve/decode/{mode}/h2d_token_puts_per_step",
                         d["h2d_token_puts_per_step"], "puts/step"))
        serve_note = (
            f"; serve us/token host={serve['host']['us_per_token']:.0f} "
            f"step={serve['step']['us_per_token']:.0f} "
            f"chunk={serve['chunk']['us_per_token']:.0f} "
            f"(resident h2d/step = {serve['step']['h2d_token_puts_per_step']:.0f})")
    except Exception as e:                              # noqa: BLE001
        rows.append(("serve/decode/error", 0.0, repr(e)[:120]))

    d8 = data["sweep"]["8"]
    gain = (1 - d8["resident_dispatch_us"] / d8["warm_dispatch_us"]) * 100
    bl, mc = cmp["baseline"], cmp["multicast"]
    derived = (
        f"resident dispatch {d8['resident_dispatch_us']:.0f}us/job vs "
        f"re-staging {d8['warm_dispatch_us']:.0f}us/job at n=8 "
        f"({gain:.0f}% less); baseline chain = "
        f"{bl['collectives']['collective-permute']} ppermutes (= 2(n-1)); "
        f"multicast = {mc['collectives']['all-reduce']} all-reduce; "
        f"wallclock ratio {bl['us'] / mc['us']:.2f}x" + serve_note)
    offload_wallclock.last_raw = raw
    return rows, derived


offload_wallclock.last_raw = {}


def stream_wallclock() -> Tuple[List[Row], str]:
    """Stream suite: hand-picked policies vs the AUTO planner, jobs/s.

    Every mode runs through ``Session.submit``; the legacy modes pin
    their policy knobs (``fuse=1, window=1`` = sequential, ``fuse=1`` =
    pipelined, ``fuse=B, window=1`` = fused) and ``auto`` lets the
    planner pick — its decision is recorded as exact-compare rows.
    """
    rows: List[Row] = []
    data = _run_child(_STREAM_CHILD)
    st, fu = data["stream"], data["fused"]
    rows.append(("stream/matmul256/8dev/seq_resident", st["seq_resident_jobs_s"],
                 "jobs/s"))
    rows.append(("stream/matmul256/8dev/seq_restage", st["seq_restage_jobs_s"],
                 "jobs/s"))
    rows.append(("stream/matmul256/8dev/pipelined", st["pipelined_jobs_s"],
                 "jobs/s"))
    rows.append(("stream/matmul256/8dev/pipelined_resident",
                 st["pipelined_resident_jobs_s"], "jobs/s"))
    rows.append(("stream/matmul256/8dev/auto", st["auto_jobs_s"], "jobs/s"))
    rows.append(("stream/matmul256/8dev/auto/fuse",
                 st["auto_decision"]["fuse"], "jobs"))
    rows.append(("stream/matmul256/8dev/auto/window",
                 st["auto_decision"]["window"], "count"))
    rows.append(("stream/fused/resident_single_dispatch",
                 fu["resident_single_dispatch_us"], "us/job"))
    for b, d in sorted(fu["per_job"].items(), key=lambda kv: int(kv[0])):
        rows.append((f"stream/fused/B{b}/dispatch",
                     d["dispatch_us_per_job"], "us/job"))
    rows.append(("stream/fused/auto_fuse_pick", fu["auto_fuse_pick"],
                 "jobs"))
    rows.append(("stream/fused/allreduce_B2",
                 fu["collectives_B2"]["all-reduce"], "collectives"))
    rows.append(("stream/fused/allreduce_B8",
                 fu["collectives_B8"]["all-reduce"], "collectives"))

    amort = (fu["resident_single_dispatch_us"]
             / max(fu["per_job"]["8"]["dispatch_us_per_job"], 1e-9))
    speedup = (st["pipelined_resident_jobs_s"]
               / max(st["seq_resident_jobs_s"], 1e-9))
    best_fresh = max(st["seq_restage_jobs_s"], st["pipelined_jobs_s"])
    auto_margin = st["auto_jobs_s"] / max(best_fresh, 1e-9)
    ad = st["auto_decision"]
    derived = (
        f"AUTO (fuse={ad['fuse']}, window={ad['window']}, "
        f"staging={ad['staging']}) {st['auto_jobs_s']:.0f} jobs/s = "
        f"{auto_margin:.2f}x the best hand-picked fresh mode "
        f"({best_fresh:.0f} jobs/s); pipelined resident "
        f"{st['pipelined_resident_jobs_s']:.0f} jobs/s vs sequential "
        f"resident {st['seq_resident_jobs_s']:.0f} jobs/s ({speedup:.2f}x); "
        f"fused B=8 dispatch "
        f"{fu['per_job']['8']['dispatch_us_per_job']:.0f}us/job vs resident "
        f"single {fu['resident_single_dispatch_us']:.0f}us/job "
        f"({amort:.1f}x amortization); fused all-reduce count "
        f"B=2 {fu['collectives_B2']['all-reduce']} == "
        f"B=8 {fu['collectives_B8']['all-reduce']}")
    stream_wallclock.last_raw = data
    return rows, derived


stream_wallclock.last_raw = {}


def serve_throughput() -> Tuple[List[Row], str]:
    """Serve suite: continuous batching vs static batches, tokens/s."""
    rows: List[Row] = []
    data = _run_child(_CONT_SERVE_CHILD, x64=False)
    co, stat = data["continuous"], data["static"]
    rows.append(("serve/throughput/continuous", co["tok_s"], "tok/s"))
    rows.append(("serve/throughput/static", stat["tok_s"], "tok/s"))
    rows.append(("serve/throughput/continuous/us_per_token",
                 co["us_per_token"], "us/token"))
    rows.append(("serve/throughput/static/us_per_token",
                 stat["us_per_token"], "us/token"))
    rows.append(("serve/throughput/inserts", co["inserts"], "prefills"))
    ratio = co["tok_s"] / max(stat["tok_s"], 1e-9)
    derived = (
        f"continuous batching {co['tok_s']:.1f} tok/s vs static "
        f"{stat['tok_s']:.1f} tok/s ({ratio:.2f}x) over a Poisson-ish "
        f"arrival trace ({co['inserts']} prefill-inserts, "
        f"{co['dispatches']} decode dispatches)")
    serve_throughput.last_raw = data
    return rows, derived


serve_throughput.last_raw = {}


def staging_wall() -> Tuple[List[Row], str]:
    """Staging sweep: host_fanout vs tree wallclock + calibrated model."""
    from repro.core.simulator import StagingCostModel, model_error

    rows: List[Row] = []
    data = _run_child(_STAGING_CHILD)
    sweep = data["sweep"]
    for n, entry in sorted(sweep.items(), key=lambda kv: int(kv[0])):
        for mode, d in entry.items():
            base = f"staging_wall/cov32MiB/{mode}/n={n}"
            rows.append((f"{base}/cold", d["cold_ms"] * 1e3, "us"))
            rows.append((f"{base}/warm", d["warm_ms"] * 1e3, "us"))
            rows.append((f"{base}/h2d", d["h2d_bytes"], "bytes"))
            rows.append((f"{base}/d2d", d["d2d_bytes"], "bytes"))

    # Calibrate the substrate cost model (host-fanout n in {1, 2} isolate
    # one upload; tree n=4 averages the edge cost over 3 edges) and record
    # measured-vs-predicted per point.  Residual rows are informational on
    # this substrate — see the module docstring; the <15% bar binds the
    # deterministic `staging` suite's model_error rows.  A hot-cache run
    # can measure hf2 <= hf1 (near-free copies), which is uncalibratable —
    # keep the measured rows and skip the residuals rather than fail.
    errs = {}
    try:
        cm = StagingCostModel.calibrate(
            hf1=sweep["1"]["host_fanout"]["warm_ms"],
            hf2=sweep["2"]["host_fanout"]["warm_ms"],
            tree_k=sweep["4"]["tree"]["warm_ms"], k=4,
        )
    except ValueError as e:
        cm = None
        rows.append(("staging_wall/cov32MiB/uncalibratable", 1.0, repr(e)[:80]))
    if cm is not None:
        for n, entry in sweep.items():
            for mode, d in entry.items():
                err = model_error(cm.predict(mode, int(n)), d["warm_ms"])
                errs[f"{mode}/n={n}"] = err
                rows.append((f"staging_wall/cov32MiB/{mode}/n={n}/"
                             "model_residual", err * 100, "percent"))
    hf8 = sweep["8"]["host_fanout"]["warm_ms"]
    tree8 = sweep["8"]["tree"]["warm_ms"]
    rows.append(("staging_wall/cov32MiB/tree_vs_hf/n=8",
                 hf8 / max(tree8, 1e-9), "speedup"))
    h2d_ratio = (sweep["8"]["host_fanout"]["h2d_bytes"]
                 / sweep["8"]["tree"]["h2d_bytes"])
    residual_note = (
        f"calibrated-model worst residual {max(errs.values()) * 100:.1f}% "
        "(substrate-noisy; the <15% bar binds the deterministic staging "
        "suite)" if errs else
        "cost model uncalibratable this run (hot-cache measurements)")
    derived = (
        f"tree {tree8:.1f}ms vs host_fanout {hf8:.1f}ms at n=8 "
        f"({hf8 / tree8:.2f}x, 32MiB operand); tree h2d is 1 upload at "
        f"every n (host_fanout moves {h2d_ratio:.0f}x the host-link bytes "
        f"at n=8); " + residual_note)
    staging_wall.last_raw = data
    return rows, derived


staging_wall.last_raw = {}
