"""Real-runtime benchmark of the framework's *own* offload overheads.

Two subprocess-isolated measurements (the bench process keeps 1 device):

* **dispatch sweep** — for n ∈ {1, 2, 4, 8} clusters, the host-side
  dispatch overhead of ``OffloadRuntime.offload()`` (time to launch,
  excluding the blocking wait) in three regimes:

    - ``cold``      first dispatch: plan build + compile + staging
    - ``warm``      warm plan, operands re-``device_put`` each job (the
                    seed's re-staging path)
    - ``resident``  warm plan, resident operands — zero ``device_put``

  plus the end-to-end µs/job and, at n=8, the baseline-vs-multicast
  wallclock and HLO collective structure (the paper's fig.-7 signature).

* **serve decode** — µs/token of ``ServeEngine`` for the legacy host
  round-trip loop vs the device-resident single-step and ``lax.scan``
  chunk paths, with per-token host->device transfer counts.

``offload_wallclock()`` returns printable rows; the raw nested dict is kept
on ``offload_wallclock.last_raw`` for ``benchmarks/run.py --json``.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from typing import List, Tuple

Row = Tuple[str, float, str]

_DISPATCH_CHILD = """
import json, statistics, time
import numpy as np
from repro.core import jobs
from repro.core.offload import OffloadRuntime, OffloadConfig, count_collectives

# Large-enough operands that phase-E staging is a real cost (the paper's
# fine-grained regime is the *ratio* of overhead to work, not tiny data).
job = jobs.make_axpy(16384)
operands, _ = job.make_instance(0)
ITERS = 60
out = {"sweep": {}}

def median_dispatch(fn, iters):
    # dispatch-only: time offload() (async launch), wait outside the timer;
    # medians — CPU-mesh collectives make per-call means noisy
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        h = fn()
        ts.append(time.perf_counter() - t0)
        h.wait()
    return statistics.median(ts) * 1e6

def median_e2e(fn, iters):
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn().wait()
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts) * 1e6

for n in (1, 2, 4, 8):
    rt = OffloadRuntime(config=OffloadConfig.extended())
    t0 = time.perf_counter()
    rt.offload(job, operands, n=n).wait()
    cold_us = (time.perf_counter() - t0) * 1e6
    warm_us = median_dispatch(lambda: rt.offload(job, operands, n=n), ITERS)
    warm_e2e_us = median_e2e(lambda: rt.offload(job, operands, n=n), ITERS)
    resident_us = median_dispatch(
        lambda: rt.offload(job, "resident", n=n), ITERS)
    resident_e2e_us = median_e2e(
        lambda: rt.offload(job, "resident", n=n), ITERS)
    out["sweep"][str(n)] = {
        "cold_us": cold_us,
        "warm_dispatch_us": warm_us,
        "resident_dispatch_us": resident_us,
        "warm_e2e_us": warm_e2e_us,
        "resident_e2e_us": resident_e2e_us,
        "recompiles_after_warm": len(rt._compiled) - 1,
    }

cmp = {}
for label, cfg in (("multicast", OffloadConfig.extended()),
                   ("baseline", OffloadConfig.baseline())):
    rt = OffloadRuntime(config=cfg)
    rt.offload(job, operands, n=8).wait()          # compile + warm
    cmp[label] = {
        "us": median_e2e(lambda: rt.offload(job, operands, n=8), 30),
        "collectives": count_collectives(rt.lowered_text(job, 8)),
    }
out["compare"] = cmp
print(json.dumps(out))
"""

_SERVE_CHILD = """
import json, time
import jax, numpy as np
from jax.sharding import Mesh
from repro import models as M
from repro.dist.sharding import param_specs, to_shardings
from repro.serve import ServeConfig, ServeEngine

cfg = M.reduced(M.get("smollm-360m"))
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
params = M.init_params(jax.random.key(0), cfg)
params = jax.device_put(params, to_shardings(param_specs(params, mesh), mesh))
prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (4, 12)).astype(np.int32)

N_NEW = 48
out = {}
for mode in ("host", "step", "chunk"):
    eng = ServeEngine(cfg, params, mesh,
                      ServeConfig(batch=4, max_len=80, decode_mode=mode,
                                  decode_chunk=8))
    eng.generate(prompts, N_NEW)                    # compile + warm
    base = dict(eng.stats)
    t0 = time.perf_counter()
    toks = eng.generate(prompts, N_NEW)
    dt = time.perf_counter() - t0
    out[mode] = {
        "us_per_token": dt / N_NEW * 1e6,
        "h2d_token_puts_per_step": (eng.stats["h2d_token_puts"]
                                    - base["h2d_token_puts"]) / N_NEW,
        "dispatches": eng.stats["xla_dispatches"] - base["xla_dispatches"],
    }
print(json.dumps(out))
"""


def _run_child(code: str, timeout: int = 570, x64: bool = True) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    # paper jobs are float64; the serving model stack is 32-bit only
    env["JAX_ENABLE_X64"] = "true" if x64 else "false"
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"bench subprocess failed: {proc.stderr[-400:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def offload_wallclock() -> Tuple[List[Row], str]:
    rows: List[Row] = []
    raw = {}

    data = _run_child(_DISPATCH_CHILD)
    raw["dispatch"] = data
    for n, d in sorted(data["sweep"].items(), key=lambda kv: int(kv[0])):
        rows.append((f"offload/axpy/{n}dev/cold", d["cold_us"], "us"))
        rows.append((f"offload/axpy/{n}dev/warm_dispatch",
                     d["warm_dispatch_us"], "us/job"))
        rows.append((f"offload/axpy/{n}dev/resident_dispatch",
                     d["resident_dispatch_us"], "us/job"))
        rows.append((f"offload/axpy/{n}dev/warm_e2e",
                     d["warm_e2e_us"], "us/job"))
        rows.append((f"offload/axpy/{n}dev/resident_e2e",
                     d["resident_e2e_us"], "us/job"))
    cmp = data["compare"]
    rows.append(("offload/axpy/multicast/8dev", cmp["multicast"]["us"], "us"))
    rows.append(("offload/axpy/baseline/8dev", cmp["baseline"]["us"], "us"))
    rows.append(("offload/multicast/chain_depth",
                 cmp["multicast"]["collectives"]["collective-permute"],
                 "collective-permutes"))
    rows.append(("offload/baseline/chain_depth",
                 cmp["baseline"]["collectives"]["collective-permute"],
                 "collective-permutes"))

    serve_note = ""
    try:
        serve = _run_child(_SERVE_CHILD, x64=False)
        raw["serve"] = serve
        for mode, d in serve.items():
            rows.append((f"serve/decode/{mode}", d["us_per_token"], "us/token"))
            rows.append((f"serve/decode/{mode}/h2d_token_puts_per_step",
                         d["h2d_token_puts_per_step"], "puts/step"))
        serve_note = (
            f"; serve us/token host={serve['host']['us_per_token']:.0f} "
            f"step={serve['step']['us_per_token']:.0f} "
            f"chunk={serve['chunk']['us_per_token']:.0f} "
            f"(resident h2d/step = {serve['step']['h2d_token_puts_per_step']:.0f})")
    except Exception as e:                              # noqa: BLE001
        rows.append(("serve/decode/error", 0.0, repr(e)[:120]))

    d8 = data["sweep"]["8"]
    gain = (1 - d8["resident_dispatch_us"] / d8["warm_dispatch_us"]) * 100
    bl, mc = cmp["baseline"], cmp["multicast"]
    derived = (
        f"resident dispatch {d8['resident_dispatch_us']:.0f}us/job vs "
        f"re-staging {d8['warm_dispatch_us']:.0f}us/job at n=8 "
        f"({gain:.0f}% less); baseline chain = "
        f"{bl['collectives']['collective-permute']} ppermutes (= 2(n-1)); "
        f"multicast = {mc['collectives']['all-reduce']} all-reduce; "
        f"wallclock ratio {bl['us'] / mc['us']:.2f}x" + serve_note)
    offload_wallclock.last_raw = raw
    return rows, derived


offload_wallclock.last_raw = {}
