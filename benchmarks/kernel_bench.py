"""Kernel microbenchmarks: wall-clock of the jit'd XLA ops on this host plus
interpret-mode validation of the Pallas kernels (TPU timing is out of scope
on a CPU container; the TPU-side performance story lives in the §Roofline
analysis of the dry-run, where BlockSpec tiling determines the claimed VMEM
footprint)."""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

Row = Tuple[str, float, str]


def _time(f, *args, iters=20) -> float:
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def kernel_table() -> Tuple[List[Row], str]:
    rng = np.random.default_rng(0)
    rows: List[Row] = []
    checks = []

    x = jnp.asarray(rng.standard_normal(1 << 16), jnp.float32)
    y = jnp.asarray(rng.standard_normal(1 << 16), jnp.float32)
    rows.append(("kernel/axpy-64k/xla", _time(lambda: ops.axpy(x, y, 2.5, impl="xla")), "us"))
    checks.append(np.allclose(np.asarray(ops.axpy(x, y, 2.5, impl="pallas")),
                              np.asarray(ref.axpy(x, y, 2.5)), rtol=1e-5, atol=1e-5))

    a = jnp.asarray(rng.standard_normal((512, 512)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((512, 512)), jnp.float32)
    rows.append(("kernel/matmul-512/xla", _time(lambda: ops.matmul(a, b, impl="xla")), "us"))
    checks.append(np.allclose(np.asarray(ops.matmul(a, b, impl="pallas")),
                              np.asarray(ref.matmul(a, b)), rtol=1e-3, atol=1e-2))

    amat = jnp.asarray(rng.standard_normal((1024, 512)), jnp.float32)
    xv = jnp.asarray(rng.standard_normal(512), jnp.float32)
    rows.append(("kernel/atax-1024x512/xla", _time(lambda: ops.atax(amat, xv, impl="xla")), "us"))
    checks.append(np.allclose(np.asarray(ops.atax(amat, xv, impl="pallas")),
                              np.asarray(ref.atax(amat, xv)), rtol=2e-3, atol=2e-3))

    d = jnp.asarray(rng.standard_normal((128, 512)), jnp.float32)
    rows.append(("kernel/covariance-128x512/xla", _time(lambda: ops.covariance(d, impl="xla")), "us"))
    checks.append(np.allclose(np.asarray(ops.covariance(d, impl="pallas")),
                              np.asarray(ref.covariance(d)), rtol=1e-4, atol=1e-4))

    q = jnp.asarray(rng.standard_normal((1, 4, 512, 64)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 4, 512, 64)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 4, 512, 64)), jnp.float32)
    rows.append(("kernel/flash-512/xla-ref",
                 _time(lambda: ops.attention(q, k, v, impl="xla")), "us"))
    checks.append(np.allclose(np.asarray(ops.attention(q, k, v, impl="pallas")),
                              np.asarray(ref.attention(q, k, v)), rtol=2e-3, atol=2e-3))

    from repro.kernels.ssm_scan import ssm_scan
    a_ = jnp.asarray(rng.uniform(0.8, 0.999, (1, 256, 256, 16)), jnp.float32)
    b_ = jnp.asarray(rng.standard_normal((1, 256, 256, 16)) * 0.1, jnp.float32)
    c_ = jnp.asarray(rng.standard_normal((1, 256, 16)), jnp.float32)
    rows.append(("kernel/ssm-scan-256/xla-ref",
                 _time(lambda: ref.ssm_scan(a_, b_, c_)), "us"))
    checks.append(np.allclose(np.asarray(ssm_scan(a_, b_, c_, interpret=True)),
                              np.asarray(ref.ssm_scan(a_, b_, c_)),
                              rtol=2e-4, atol=2e-4))

    derived = f"pallas-interpret allclose: {sum(checks)}/{len(checks)}"
    return rows, derived
